//! `tpufleet` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   simulate   run a fleet simulation and print the MPG decomposition
//!   figures    regenerate any (or all) of the paper's figures/tables
//!   train      end-to-end: train the AOT transformer through PJRT
//!   run-model  execute one artifact and report measured Program Goodput
//!   hlo-cost   FLOP/byte analysis of an HLO text file
//!   overlap    §5.1 collective-overlap case study numbers
//!   monitor    live MPG over a span/event stream (bounded memory)

use tpufleet::fleet::ChipGeneration;
use tpufleet::hlo::{CostAnalysis, HloModule};
use tpufleet::metrics::{goodput, WindowedLedger};
use tpufleet::monitor::{
    ckpt, http, merge, proto, series_json, snapshot_json, MonitorLedger, StreamStats,
};
use tpufleet::report::{self, figures};
use tpufleet::roofline;
use tpufleet::runtime::{Engine, Manifest, Trainer};
use tpufleet::sim::cache::SIM_BEHAVIOR_VERSION;
use tpufleet::sim::{
    shard, JobSource, LedgerMode, SimConfig, Simulation, SweepCache, SweepRunner, SweepSpec,
};
use tpufleet::util::cli::Args;
use tpufleet::util::{pool, Rng};
use tpufleet::xlaopt;

const USAGE: &str = "\
tpufleet — ML fleet efficiency simulator + MPG instrumentation

USAGE: tpufleet <command> [options]

COMMANDS:
  simulate   [--days N] [--seed S] [--arrivals-per-hour R] [--no-failures]
             run the fleet simulator; print the MPG decomposition by segment
  figures    <fig1|fig4|fig6|fig12|fig13|fig14|fig15|fig16|table2
             |attribution|monitor-series|all>
             [--csv DIR] [--seed S] [--workers W]
             regenerate paper figures/tables; `all` fans the independent
             generators out over the worker pool and streams them in order
  train      [--steps N] [--lr X] [--seed S] [--artifacts DIR]
             end-to-end training of the AOT transformer via PJRT (L3->L1)
  run-model  <artifact> [--iters N] [--artifacts DIR]
             execute an artifact; report step time + measured PG vs roofline
  hlo-cost   <file.hlo.txt>   FLOP/byte cost analysis of an HLO module
  overlap    print the §5.1 collective-overlap case-study numbers
  ablate     [--seed S] [--workers W] one-design-choice-at-a-time ablation
             matrix (runs as a parallel sweep; W=0 means one per core)
  attribution [--days N] [--seed S] [--arrivals-per-hour R] [--no-failures]
             [--degrade PRESET] [--windowed] [--out FILE]
             run a fleet simulation and print the per-layer MPG waterfall:
             chip-time attributed to each ML-stack layer (model, compiler,
             framework, data, hardware, scheduling) and the fleet MPG
             recovered if each layer were made ideal, ranked — the paper's
             bottleneck-identification workflow. --degrade regresses one
             layer (none data-3x framework-3x compiler-3x hardware-3x
             scheduling-8x); --windowed accounts through the streaming
             ledger (bit-identical report); --out writes the JSON report
  sweep      [--days N] [--seed S] [--workers W] [--arrivals-per-hour R]
             [--policies a,b,..] [--fleets a,b,..] [--job-mixes a,b,..]
             [--failure-mults 0,1,3] [--degrades none,data-3x,..]
             [--out FILE] [--progress]
             [--no-cache] [--cache-dir DIR] [--cache-max-mb N]
             [--cache-stats] [--shards N] [--shard-cmd CMD]
             [--windowed | --full-ledger] [--materialize-trace]
             run a policy x fleet x job-size x failure-rate grid on a
             worker pool, streaming rows into one JSON report as variants
             finish (memory stays O(workers)); each variant accounts into
             the streaming windowed ledger (no span retention; per-variant
             memory O(windows x jobs)) — --full-ledger forces full-span
             accounting, which produces bit-identical reports, for
             debugging; --progress reports n/total + ETA on stderr;
             results persist under .sweep-cache/ so a repeated grid is
             served from cache bit-identically; --cache-max-mb caps the
             cache (LRU eviction) and --cache-stats reports
             hits/misses/bytes/age after the run; --shards N partitions
             the grid across N worker subprocesses (sharing one cache;
             merged report is byte-identical to the single-process run)
             and --shard-cmd overrides how workers are launched (default:
             this binary); --materialize-trace pre-generates every
             variant's job list instead of streaming it from the O(1)
             partition descriptor — results and report bytes are
             identical; use it to cross-check the descriptor path
             (policies: default no-preemption no-defrag no-anti-thrash
             headroom-15; fleets: default small large c-only; job-mixes:
             default xl-heavy small-heavy; degrades: none data-3x
             framework-3x compiler-3x hardware-3x scheduling-8x — each
             regresses one stack layer; every report row carries the
             per-layer attribution section)
  trace      generate [<out.json>] [--hours H] [--seed S] [--out FILE]
             | replay <in.json> [--days N] [--seed S] [--windowed]
             [--out FILE]
             generate a workload trace, or replay one through the
             simulator; replay's --windowed accounts through the
             streaming ledger (bit-identical fleet report) and --out
             writes the per-layer attribution JSON
  monitor    [--in FILE[,FILE..]] [--width-s W] [--ring-windows N]
             [--snapshot-every SECS] [--out FILE] [--batch] [--follow]
             [--merge] [--stream-ids A,B,..] [--reorder-cap N]
             [--listen ADDR] [--series-out FILE] [--progress]
             ingest a span/event stream (stdin, or --in FILE; --follow
             tails the file until an `end` line) through the rolling
             monitor ledger: O(ring-windows x live jobs) cells no matter
             how long the stream runs, whole-stream totals exact. Writes
             an MPG + per-layer-attribution snapshot JSON to --out (or
             stdout) at the end, and every SECS stream-seconds with
             --snapshot-every; --batch replays the same stream through
             the batch windowed ledger instead and emits a byte-identical
             snapshot (the CI cross-mode `cmp` gate). --merge treats
             --in A,B,C as N concurrent cell streams and interleaves
             them deterministically under the cross-stream watermark
             (= min of per-stream watermarks; bounded per-stream reorder
             buffers of --reorder-cap events apply backpressure, and the
             merged snapshot is byte-identical to --merge --batch);
             --listen ADDR serves GET /snapshot /streams /series over
             HTTP while ingesting; --series-out writes the rolling
             per-window series JSON alongside the final snapshot
  monitor record [--days N] [--seed S] [--arrivals-per-hour R]
             [--no-failures] [--stream-id ID] [--out FILE]
             run the simulator with a stream recorder attached and write
             the replayable span stream (line protocol with a stream-id
             framing header; see README)

(`sweep-worker` is the internal subcommand `sweep --shards` spawns; it
runs one shard manifest and writes a shard report for the coordinator.)

Unknown flags are rejected with the offending subcommand named; --out,
--workers, --windowed, and --progress spell the same thing everywhere
they appear.
";

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    let code = match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "figures" => cmd_figures(&args),
        "train" => cmd_train(&args),
        "run-model" => cmd_run_model(&args),
        "hlo-cost" => cmd_hlo_cost(&args),
        "overlap" => cmd_overlap(&args),
        "ablate" => cmd_ablate(&args),
        "attribution" => cmd_attribution(&args),
        "sweep" => cmd_sweep(&args),
        "sweep-worker" => cmd_sweep_worker(&args),
        "trace" => cmd_trace(&args),
        "monitor" => cmd_monitor(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown command: {other}\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

/// Simulation-shaping flags shared by every subcommand that runs the
/// simulator on a generated workload.
const SIM_FLAGS: [&str; 4] = ["days", "seed", "arrivals-per-hour", "no-failures"];

/// The CLI consistency gate: every subcommand declares its flag
/// vocabulary and anything else exits 2 with the subcommand named —
/// a typo'd `--sed 7` can no longer silently run with the default seed.
fn check_flags(args: &Args, cmd: &str, known: &[&str]) -> Option<i32> {
    if let Err(e) = args.reject_unknown(cmd, known) {
        eprintln!("{e}");
        return Some(2);
    }
    None
}

fn cmd_simulate(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "simulate", &SIM_FLAGS) {
        return code;
    }
    let days = args.get_f64("days", 7.0);
    let mut cfg = SimConfig {
        seed: args.get_u64("seed", 42),
        duration_s: days * 24.0 * 3600.0,
        ..Default::default()
    };
    cfg.generator.arrivals_per_hour = args.get_f64("arrivals-per-hour", 10.0);
    if args.has_flag("no-failures") {
        cfg.failures = false;
    }
    eprintln!("simulating {days} days (seed {})...", cfg.seed);
    let t0 = std::time::Instant::now();
    let mut sim = Simulation::new(cfg.clone());
    let res = sim.run();
    eprintln!(
        "done in {:.2?}: {} arrived, {} completed, {} preemptions, {} failures",
        t0.elapsed(),
        res.arrived_jobs,
        res.completed_jobs,
        res.preemptions,
        res.failures_injected
    );
    print!("{}", figures::mpg_summary(&sim.ledger, 0.0, cfg.duration_s).to_ascii());
    let fleet = goodput::report(&sim.ledger, 0.0, cfg.duration_s, |_| true);
    println!(
        "\nfleet MPG = SG {:.3} x RG {:.3} x PG {:.3} = {:.3}",
        fleet.sg,
        fleet.rg,
        fleet.pg,
        fleet.mpg()
    );
    0
}

fn cmd_figures(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "figures", &["csv", "seed", "workers"]) {
        return code;
    }
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let seed = args.get_u64("seed", 0xF1EE7);
    let csv_dir = args.get("csv");
    let workers = args.get_usize("workers", 0);
    let names: Vec<&str> =
        if which == "all" { figures::FIGURE_NAMES.to_vec() } else { vec![which] };
    // When several figures fan out below, the outer pool is the only
    // parallelism: inner pools (fig13's per-month fan) run serial so a
    // `--workers` bound actually bounds total threads. A standalone
    // figure instead gives the user's bound to the inner pool directly
    // (the outer pool inlines its single item).
    let inner_workers = if names.len() > 1 { 1 } else { workers };
    let mut gens: Vec<(&str, figures::FigureGen)> = Vec::new();
    for name in names {
        match figures::generator(name, seed, inner_workers) {
            Some(g) => gens.push((name, g)),
            None => {
                eprintln!("unknown figure: {name}");
                return 2;
            }
        }
    }
    // The generators are independent, so `figures all` fans them out over
    // the sweep/pool substrate and streams the tables back in paper
    // order: fig1 prints first even when table2 finishes earlier, and
    // output is identical to the serial path for any worker count.
    let mut code = 0;
    pool::parallel_map_streaming(
        gens,
        workers,
        |_, (name, gen)| (name, gen()),
        |_, (name, t)| {
            println!("{}", t.to_ascii());
            if let Some(dir) = csv_dir {
                if let Err(e) = t.save_csv(dir, name) {
                    eprintln!("csv write failed: {e}");
                    code = 1;
                } else {
                    eprintln!("wrote {dir}/{name}.csv");
                }
            }
        },
    );
    code
}

fn cmd_train(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "train", &["steps", "lr", "seed", "artifacts"]) {
        return code;
    }
    let steps = args.get_usize("steps", 300);
    let lr = args.get_f64("lr", 0.2) as f32;
    let seed = args.get_u64("seed", 42) as i32;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    match run_training(&dir, steps, lr, seed) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

fn run_training(
    dir: &std::path::Path,
    steps: usize,
    lr: f32,
    seed: i32,
) -> anyhow::Result<()> {
    let engine = Engine::new(dir)?;
    eprintln!("platform: {}", engine.platform());
    let cost = engine.module_cost("train_step")?;
    let mut trainer = Trainer::new(engine, seed)?;
    let report = trainer.train(steps, lr, (steps / 20).max(1))?;
    let acc = trainer.eval_next_token_accuracy()?;
    let cpu = ChipGeneration::Cpu.spec();
    let est = roofline::estimate(&cost, cpu, false);
    let pg = roofline::program_goodput(est.ideal_compute_s, report.mean_step_seconds());
    println!("steps:            {}", report.steps);
    println!("loss:             {:.4} -> {:.4}", report.first_loss(), report.last_loss());
    println!("next-token acc:   {:.3}", acc);
    println!("mean step:        {:.2} ms", report.mean_step_seconds() * 1e3);
    println!("HLO useful FLOPs: {:.3e}", cost.flops);
    println!("ideal step (cpu): {:.2} ms", est.ideal_compute_s * 1e3);
    println!("measured PG:      {:.3}", pg);
    Ok(())
}

fn cmd_run_model(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "run-model", &["iters", "artifacts"]) {
        return code;
    }
    let Some(name) = args.positional.first().map(|s| s.to_string()) else {
        eprintln!("usage: tpufleet run-model <artifact> [--iters N]");
        return 2;
    };
    let iters = args.get_usize("iters", 20);
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    match run_model(&dir, &name, iters) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("run-model failed: {e:#}");
            1
        }
    }
}

fn run_model(dir: &std::path::Path, name: &str, iters: usize) -> anyhow::Result<()> {
    let mut engine = Engine::new(dir)?;
    let spec = engine.manifest.artifact(name)?.clone();
    let mut rng = Rng::new(7);
    let inputs: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|t| {
            let n = t.elements();
            match t.dtype.as_str() {
                "int32" => {
                    let v: Vec<i32> = (0..n).map(|_| rng.below(256) as i32).collect();
                    Engine::literal_i32(&v, &t.shape)
                }
                _ => {
                    let v: Vec<f32> =
                        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect();
                    Engine::literal_f32(&v, &t.shape)
                }
            }
        })
        .collect::<anyhow::Result<_>>()?;

    engine.prepare(name)?;
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let (_out, dt) = engine.execute_timed(name, &inputs)?;
        times.push(dt);
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let median = times[times.len() / 2];
    let cost = engine.module_cost(name)?;
    let cpu = ChipGeneration::Cpu.spec();
    let est = roofline::estimate(&cost, cpu, false);
    let pg = roofline::program_goodput(est.ideal_compute_s, median);
    println!("artifact:       {name}");
    println!("median step:    {:.3} ms over {iters} iters", median * 1e3);
    println!("useful FLOPs:   {:.3e}", cost.flops);
    println!("bytes (proxy):  {:.3e}", cost.bytes);
    println!("intensity:      {:.2} FLOP/B (knee {:.2})", est.intensity, est.knee);
    println!("ideal (cpu):    {:.3} ms", est.ideal_compute_s * 1e3);
    println!("measured PG:    {:.3}", pg);
    Ok(())
}

fn cmd_hlo_cost(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "hlo-cost", &[]) {
        return code;
    }
    let Some(path) = args.positional.first() else {
        eprintln!("usage: tpufleet hlo-cost <file.hlo.txt>");
        return 2;
    };
    match HloModule::parse_file(path) {
        Ok(module) => {
            let cost = CostAnalysis::new(&module).module_cost();
            println!("module:           {}", module.name);
            println!("computations:     {}", module.computations.len());
            println!("useful FLOPs:     {:.4e}", cost.flops);
            println!("transcendentals:  {:.4e}", cost.transcendentals);
            println!("bytes (proxy):    {:.4e}", cost.bytes);
            println!("intensity:        {:.2} FLOP/B", cost.intensity());
            if cost.unknown_trip_counts > 0 {
                println!(
                    "WARNING: {} while loop(s) with unresolved trip counts (lower bound)",
                    cost.unknown_trip_counts
                );
            }
            let mut ops: Vec<(&String, &f64)> = cost.by_opcode.iter().collect();
            ops.sort_by(|a, b| b.1.total_cmp(a.1));
            println!("top opcodes by FLOPs:");
            for (op, f) in ops.iter().take(8) {
                println!("  {op:<22} {f:.4e}");
            }
            0
        }
        Err(e) => {
            eprintln!("hlo-cost failed: {e:#}");
            1
        }
    }
}

fn cmd_ablate(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "ablate", &["seed", "workers"]) {
        return code;
    }
    let seed = args.get_u64("seed", 0xAB1A);
    let workers = args.get_usize("workers", 0);
    eprintln!("running 8 variant simulations on one 7-day trace (sweep)...");
    let ab = figures::ablations_with_workers(seed, workers);
    println!("{}", ab.table.to_ascii());
    0
}

/// The stack-layer MPG attribution waterfall: run one simulation, reduce
/// it to per-layer chip-time, and rank layers by the fleet MPG recovered
/// if each were made ideal (the paper's bottleneck-identification
/// workflow). `--windowed` accounts through the streaming ledger instead
/// of retained spans — the report is bit-identical either way, which the
/// CI `cmp` gate checks on the real binary.
fn cmd_attribution(args: &Args) -> i32 {
    use tpufleet::metrics::AttributionReport;

    let known = ["days", "seed", "arrivals-per-hour", "no-failures", "degrade", "windowed", "out"];
    if let Some(code) = check_flags(args, "attribution", &known) {
        return code;
    }
    let days = args.get_f64("days", 7.0);
    let mut cfg = SimConfig {
        seed: args.get_u64("seed", 42),
        duration_s: days * 24.0 * 3600.0,
        ..Default::default()
    };
    cfg.generator.arrivals_per_hour = args.get_f64("arrivals-per-hour", 10.0);
    if args.has_flag("no-failures") {
        cfg.failures = false;
    }
    if let Some(preset) = args.get("degrade") {
        if !tpufleet::sim::sweep::apply_degrade_preset(&mut cfg, preset) {
            eprintln!("unknown degrade preset: {preset}");
            return 2;
        }
    }
    let windowed = args.has_flag("windowed");
    eprintln!(
        "attributing {days} days (seed {}, {} accounting)...",
        cfg.seed,
        if windowed { "windowed" } else { "full-span" }
    );
    let t0 = std::time::Instant::now();
    let mode = if windowed {
        tpufleet::sim::sweep::summary_ledger_mode()
    } else {
        LedgerMode::Full
    };
    let mut sim = Simulation::new(cfg).ledger_mode(mode);
    let res = sim.run();
    eprintln!(
        "done in {:.2?}: {} arrived, {} completed, {} preemptions, {} failures",
        t0.elapsed(),
        res.arrived_jobs,
        res.completed_jobs,
        res.preemptions,
        res.failures_injected
    );
    let fleet = sim.fleet_goodput();
    let att = AttributionReport::of(&fleet);
    println!(
        "fleet MPG = SG {:.3} x RG {:.3} x PG {:.3} = {:.4}",
        fleet.sg,
        fleet.rg,
        fleet.pg,
        fleet.mpg()
    );
    println!("{}", att.table("Stack-layer MPG attribution waterfall").to_ascii());
    println!("bottleneck layer: {}", att.bottleneck().name());
    if let Some(out) = args.get("out") {
        if let Err(e) = std::fs::write(out, att.to_json().to_string_pretty()) {
            eprintln!("writing {out} failed: {e}");
            return 1;
        }
        eprintln!("wrote {out}");
    }
    0
}

/// Named policy variants for the sweep grid (shared preset table).
fn sweep_policy(cfg: &mut SimConfig, name: &str) -> bool {
    tpufleet::sim::sweep::apply_policy_preset(cfg, name)
}

/// Named fleet mixes for the sweep grid.
fn sweep_fleet(cfg: &mut SimConfig, name: &str) -> bool {
    use tpufleet::fleet::ChipGeneration as G;
    cfg.static_fleet = match name {
        "default" => return true,
        "small" => vec![(G::TpuB, 12), (G::TpuC, 16), (G::TpuD, 10)],
        "large" => vec![(G::TpuB, 48), (G::TpuC, 64), (G::TpuD, 40)],
        "c-only" => {
            cfg.generator.gen_mix = vec![(G::TpuC, 1.0)];
            vec![(G::TpuC, 40)]
        }
        _ => return false,
    };
    true
}

/// Named job-size mixes for the sweep grid.
fn sweep_job_mix(cfg: &mut SimConfig, name: &str) -> bool {
    use tpufleet::workload::MixDrift;
    match name {
        "default" => {}
        "xl-heavy" => {
            cfg.generator.size_mix = MixDrift::constant([0.20, 0.25, 0.25, 0.30]);
            cfg.generator.xl_pods = (5, 8);
        }
        "small-heavy" => {
            cfg.generator.size_mix = MixDrift::constant([0.60, 0.25, 0.10, 0.05]);
        }
        _ => return false,
    }
    true
}

const SWEEP_DEFAULT_DAYS: f64 = 3.0;
const SWEEP_DEFAULT_SEED: u64 = 0x5EE9;
const SWEEP_DEFAULT_ARRIVALS: f64 = 8.0;

/// Ledger mode for sweep variants: streaming windowed accounting unless
/// `--full-ledger` forces span retention (bit-identical either way; the
/// flag exists for debugging and the CI cross-mode `cmp`).
fn sweep_ledger_mode(args: &Args) -> LedgerMode {
    if args.has_flag("full-ledger") {
        LedgerMode::Full
    } else {
        tpufleet::sim::sweep::summary_ledger_mode()
    }
}

/// Shared cache wiring for `sweep`, its coordinator, and `sweep-worker`:
/// `--no-cache` disables, `--cache-dir` relocates, `--cache-max-mb` caps
/// the footprint with LRU eviction. A malformed cap is an error (exit
/// code in `Err`), not a silently uncapped cache.
fn sweep_cache_from_args(args: &Args) -> Result<Option<SweepCache>, i32> {
    if args.has_flag("no-cache") {
        return Ok(None);
    }
    let dir = args.get("cache-dir");
    let cache = dir.map(SweepCache::new).unwrap_or_else(SweepCache::default_dir);
    if args.has_flag("cache-max-mb") {
        eprintln!("bad --cache-max-mb value: the flag requires an integer MiB count");
        return Err(2);
    }
    match args.get("cache-max-mb") {
        None => Ok(Some(cache)),
        Some(s) => match s.parse::<u64>() {
            Ok(mb) => Ok(Some(cache.with_max_bytes(mb.saturating_mul(1024 * 1024)))),
            Err(_) => {
                eprintln!("bad --cache-max-mb value: {s} (want an integer MiB count)");
                Err(2)
            }
        },
    }
}

/// The report's `spec` header — shared by the serial writer and the shard
/// coordinator so both emit identical bytes. Embeds the simulation
/// behavior version: a report is only comparable to runs of the same
/// engine behavior.
fn sweep_spec_json(args: &Args, total: usize) -> tpufleet::util::Json {
    use tpufleet::util::Json;
    Json::obj(vec![
        ("days", Json::num(args.get_f64("days", SWEEP_DEFAULT_DAYS))),
        ("seed", Json::str(&format!("{:#x}", args.get_u64("seed", SWEEP_DEFAULT_SEED)))),
        ("workers", Json::num(args.get_usize("workers", 0) as f64)),
        (
            "arrivals_per_hour",
            Json::num(args.get_f64("arrivals-per-hour", SWEEP_DEFAULT_ARRIVALS)),
        ),
        // The *configured* retry budget (not attempts actually used —
        // those are run-dependent telemetry and live on stderr only), so
        // a faulted run that recovers emits a report byte-identical to
        // the clean run under the same flags.
        ("retries", Json::num(args.get_usize("retries", 0) as f64)),
        ("behavior_version", Json::num(SIM_BEHAVIOR_VERSION as f64)),
        ("variant_count", Json::num(total as f64)),
    ])
}

fn print_cache_stats(cache: &SweepCache, hits: u64, misses: u64) {
    let st = cache.stats();
    eprintln!(
        "cache stats: {hits} hits / {misses} misses this run; {} entries, {:.2} MiB \
         in {}, entry age {:.0}s-{:.0}s; {} evicted by this process; \
         {} corrupt quarantined",
        st.entries,
        st.bytes as f64 / (1024.0 * 1024.0),
        cache.dir().display(),
        st.newest_age_s,
        st.oldest_age_s,
        st.evictions,
        st.corrupt,
    );
}

/// Post-sweep quarantine telemetry: unreadable entries the run (or a
/// previous one) renamed aside. Unconditional — unlike `--cache-stats`,
/// corruption is worth a line even when nobody asked.
fn warn_corrupt_entries(cache: &Option<SweepCache>) {
    if let Some(c) = cache {
        let corrupt = c.stats().corrupt;
        if corrupt > 0 {
            eprintln!(
                "cache: {corrupt} corrupt entr{} quarantined as .corrupt in {} \
                 (re-simulated on miss; delete the .corrupt files to reclaim space)",
                if corrupt == 1 { "y" } else { "ies" },
                c.dir().display(),
            );
        }
    }
}

/// Build the sweep grid from the CLI axes. Prints the offending flag and
/// returns the exit code on bad input.
fn build_sweep_spec(args: &Args) -> Result<SweepSpec, i32> {
    let days = args.get_f64("days", SWEEP_DEFAULT_DAYS);
    let seed = args.get_u64("seed", SWEEP_DEFAULT_SEED);
    let workers = args.get_usize("workers", 0);
    let arrivals = args.get_f64("arrivals-per-hour", SWEEP_DEFAULT_ARRIVALS);
    let list = |key: &str, default: &str| -> Vec<String> {
        args.get(key)
            .unwrap_or(default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    };
    let policies = list("policies", "default,no-preemption,headroom-15");
    let fleets = list("fleets", "default,small");
    let job_mixes = list("job-mixes", "default");
    let degrades = list("degrades", "none");
    let fail_strs = list("failure-mults", "1");
    // Repeated axis values would produce duplicate variant names (which
    // SweepSpec rejects) and ambiguous report rows — fail fast instead.
    for (axis, vals) in [
        ("policies", &policies),
        ("fleets", &fleets),
        ("job-mixes", &job_mixes),
        ("degrades", &degrades),
    ] {
        if let Some(dup) = vals.iter().enumerate().find_map(|(i, s)| {
            vals[..i].contains(s).then_some(s)
        }) {
            eprintln!("duplicate value in --{axis}: {dup}");
            return Err(2);
        }
    }
    let mut fail_mults: Vec<f64> = Vec::new();
    for s in &fail_strs {
        match s.parse::<f64>() {
            // Dedup on the PARSED value: "1" and "1.0" would collide as
            // the same variant name even though the strings differ.
            Ok(m) if m >= 0.0 => {
                if fail_mults.contains(&m) {
                    eprintln!("duplicate value in --failure-mults: {s}");
                    return Err(2);
                }
                fail_mults.push(m);
            }
            _ => {
                eprintln!("bad failure multiplier: {s}");
                return Err(2);
            }
        }
    }

    let mut spec = SweepSpec::new().workers(workers);
    for pol in &policies {
        for fl in &fleets {
            for jm in &job_mixes {
                for dg in &degrades {
                    for &fm in &fail_mults {
                        let mut cfg = SimConfig {
                            duration_s: days * 24.0 * 3600.0,
                            ..Default::default()
                        };
                        cfg.generator.arrivals_per_hour = arrivals;
                        if !sweep_policy(&mut cfg, pol) {
                            eprintln!("unknown policy variant: {pol}");
                            return Err(2);
                        }
                        if !sweep_fleet(&mut cfg, fl) {
                            eprintln!("unknown fleet variant: {fl}");
                            return Err(2);
                        }
                        if !sweep_job_mix(&mut cfg, jm) {
                            eprintln!("unknown job-mix variant: {jm}");
                            return Err(2);
                        }
                        if !tpufleet::sim::sweep::apply_degrade_preset(&mut cfg, dg) {
                            eprintln!("unknown degrade variant: {dg}");
                            return Err(2);
                        }
                        cfg.failure_rate_mult = fm;
                        if fm == 0.0 {
                            cfg.failures = false;
                        }
                        let name = format!("{pol}+{fl}+{jm}+{dg}+fail{fm}");
                        spec.push_derived_seed(name, cfg, seed);
                    }
                }
            }
        }
    }
    Ok(spec)
}

const SWEEP_FLAGS: [&str; 22] = [
    "days",
    "seed",
    "workers",
    "arrivals-per-hour",
    "policies",
    "fleets",
    "job-mixes",
    "failure-mults",
    "degrades",
    "out",
    "progress",
    "no-cache",
    "cache-dir",
    "cache-max-mb",
    "cache-stats",
    "shards",
    "shard-cmd",
    "retries",
    "inject-faults",
    "windowed",
    "full-ledger",
    "materialize-trace",
];

fn cmd_sweep(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "sweep", &SWEEP_FLAGS) {
        return code;
    }
    // Hidden chaos-test path: arm the fault registry before any site is
    // hit (equivalent to exporting TPUFLEET_FAULTS).
    if let Some(spec) = args.get("inject-faults") {
        tpufleet::util::fault::install(spec);
    }
    // `--windowed` names the default accounting explicitly (the same
    // spelling attribution, trace replay, and monitor use); it cannot be
    // combined with the full-span debugging mode.
    if args.has_flag("windowed") && args.has_flag("full-ledger") {
        eprintln!("sweep: --windowed and --full-ledger are mutually exclusive");
        return 2;
    }
    let mut spec = match build_sweep_spec(args) {
        Ok(spec) => spec,
        Err(code) => return code,
    };
    // Convert every descriptor-backed variant to an explicit materialized
    // trace up front. Results (and report bytes) are identical to the
    // descriptor path by construction — the CI shard-smoke gate `cmp`s a
    // 2-shard descriptor run against this path to prove it — but configs
    // go from O(1) to O(jobs), so this is a verification tool, not a
    // default.
    if args.has_flag("materialize-trace") {
        for v in &mut spec.variants {
            if let JobSource::Partition { part_index, part_count } = v.cfg.source {
                let mut gcfg = v.cfg.generator.clone();
                gcfg.duration_s = v.cfg.duration_s;
                let jobs: Vec<_> =
                    tpufleet::workload::TracePartition::new(gcfg, part_index, part_count)
                        .collect();
                v.cfg.source = JobSource::materialized(jobs);
            }
        }
    }
    // A bare `--shards` (no value) parses as a flag; running serially
    // would silently ignore the operator's intent to shard — reject it.
    if args.has_flag("shards") {
        eprintln!("bad --shards value: the flag requires an integer >= 1");
        return 2;
    }
    match args.get("shards") {
        None => cmd_sweep_serial(args, spec),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => cmd_sweep_coordinator(args, spec, n),
            _ => {
                eprintln!("bad --shards value: {s} (want an integer >= 1)");
                2
            }
        },
    }
}

fn cmd_sweep_serial(args: &Args, spec: SweepSpec) -> i32 {
    use std::io::Write;

    let days = args.get_f64("days", SWEEP_DEFAULT_DAYS);
    let seed = args.get_u64("seed", SWEEP_DEFAULT_SEED);
    let workers = args.get_usize("workers", 0);
    let out_path = args.get("out").unwrap_or("sweep_report.json").to_string();
    let progress = args.has_flag("progress");
    let cache = match sweep_cache_from_args(args) {
        Ok(cache) => cache,
        Err(code) => return code,
    };
    let total = spec.len();
    eprintln!(
        "sweeping {total} variants x {days} days on {} workers (seed {seed:#x}, cache {})...",
        if workers == 0 { "auto".to_string() } else { workers.to_string() },
        match &cache {
            Some(c) => c.dir().display().to_string(),
            None => "off".to_string(),
        }
    );
    let t0 = std::time::Instant::now();

    // Stream the report: the spec header goes out first, then one compact
    // row per variant as it finishes, in spec order. Nothing grid-sized
    // is held in memory (each worker drops its Simulation after reducing
    // it), and the bytes are a pure function of the grid — a warm re-run
    // served from the cache writes a bit-identical file. Wall-clock goes
    // to stderr only, for exactly that reason.
    let file = match std::fs::File::create(&out_path) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("creating {out_path} failed: {e}");
            return 1;
        }
    };
    let mut out = std::io::BufWriter::new(file);
    let spec_json = sweep_spec_json(args, total);
    let mut io_err: Option<std::io::Error> = None;
    if let Err(e) = shard::write_report_header(&mut out, &spec_json) {
        io_err = Some(e);
    }

    let mut table = report::Table::new(
        "Scenario sweep — fleet goodputs per variant",
        &[
            "variant",
            "SG",
            "RG",
            "PG",
            "MPG",
            "completed",
            "preempt",
            "failures",
            "bottleneck",
            "src",
        ],
    );
    let mut done = 0usize;
    let mut hits = 0usize;
    let mode = sweep_ledger_mode(args);
    SweepRunner::run_streaming_summaries_with_mode(spec, cache.as_ref(), mode, |s| {
        let g = &s.goodput;
        table.row(vec![
            s.name.clone(),
            format!("{:.3}", g.sg),
            format!("{:.3}", g.rg),
            format!("{:.3}", g.pg),
            format!("{:.3}", g.mpg()),
            s.result.completed_jobs.to_string(),
            s.result.preemptions.to_string(),
            s.result.failures_injected.to_string(),
            tpufleet::metrics::AttributionReport::of(g).bottleneck().name().to_string(),
            if s.cached { "cache".to_string() } else { "sim".to_string() },
        ]);
        let row = shard::summary_row_json(&s);
        if io_err.is_none() {
            if let Err(e) = shard::write_report_row(&mut out, done, &row) {
                // Surface it NOW (the grid keeps running — with the cache
                // on, every finished variant still persists, so a re-run
                // after fixing the disk is all hits; ctrl-C is safe).
                eprintln!("report write failed, continuing grid: {e}");
                io_err = Some(e);
            }
        }
        done += 1;
        if s.cached {
            hits += 1;
        }
        if progress {
            let elapsed = t0.elapsed().as_secs_f64();
            // Rate from *simulated* variants only: cache hits stream back
            // near-instantly and would make the ETA wildly optimistic on
            // a partially warm cache.
            let simmed = done - hits;
            let eta = if simmed > 0 {
                elapsed / simmed as f64 * (total - done) as f64
            } else {
                0.0
            };
            eprintln!(
                "progress: {done}/{total} ({:.0}%) elapsed {elapsed:.1}s eta {eta:.1}s \
                 ({hits} cached) {}",
                done as f64 / total.max(1) as f64 * 100.0,
                s.name
            );
        }
    });
    // The summary table prints even when the report file failed — the
    // grid still ran to completion and stdout is all the user has left.
    println!("{}", table.to_ascii());
    let finish = match io_err {
        Some(e) => Err(e),
        None => shard::write_report_footer(&mut out).and_then(|()| out.flush()),
    };
    if let Err(e) = finish {
        eprintln!("writing {out_path} failed: {e}");
        return 1;
    }
    eprintln!(
        "done in {:.2}s ({hits}/{total} cache hits); wrote {out_path}",
        t0.elapsed().as_secs_f64()
    );
    warn_corrupt_entries(&cache);
    if args.has_flag("cache-stats") {
        match &cache {
            Some(c) => print_cache_stats(c, hits as u64, (total - hits) as u64),
            None => eprintln!("cache stats: cache disabled (--no-cache)"),
        }
    }
    0
}

/// The shard coordinator behind `sweep --shards N`: write one manifest
/// per shard, spawn `sweep-worker` subprocesses (or whatever
/// `--shard-cmd` names — an ssh wrapper makes this span machines), stream
/// their progress into one aggregated stderr feed, and merge the shard
/// reports into a file byte-identical to the single-process run. All
/// shards share one `.sweep-cache/`, which doubles as the resume point:
/// if a worker dies, every variant it finished is already persisted, so
/// re-running the same command re-derives only the cold entries.
fn cmd_sweep_coordinator(args: &Args, spec: SweepSpec, shards: usize) -> i32 {
    use std::io::Write;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use tpufleet::util::subproc;

    let out_path = args.get("out").unwrap_or("sweep_report.json").to_string();
    let progress = args.has_flag("progress");
    // A bare `--retries` (no value) parses as a flag; silently running
    // without a retry budget would defeat the operator's intent.
    if args.has_flag("retries") {
        eprintln!("bad --retries value: the flag requires an integer >= 0");
        return 2;
    }
    let retries: u32 = match args.get("retries") {
        None => 0,
        Some(s) => match s.parse() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("bad --retries value: {s} (want an integer >= 0)");
                return 2;
            }
        },
    };
    let cache = match sweep_cache_from_args(args) {
        Ok(cache) => cache,
        Err(code) => return code,
    };
    let total = spec.len();
    let spec_json = sweep_spec_json(args, total);

    let shard_dir = std::path::PathBuf::from(format!("{out_path}.shards"));
    if let Err(e) = std::fs::create_dir_all(&shard_dir) {
        eprintln!("creating {} failed: {e}", shard_dir.display());
        return 1;
    }
    if args.has_flag("shard-cmd") {
        eprintln!("bad --shard-cmd value: the flag requires a worker command string");
        return 2;
    }
    let base: Vec<String> = match args.get("shard-cmd") {
        Some(s) => {
            let v: Vec<String> = s.split_whitespace().map(String::from).collect();
            if v.is_empty() {
                eprintln!("empty --shard-cmd");
                return 2;
            }
            v
        }
        None => match std::env::current_exe() {
            Ok(p) => vec![p.display().to_string()],
            Err(e) => {
                eprintln!("cannot locate own binary to spawn workers: {e}");
                return 1;
            }
        },
    };
    let report_path = |k: usize| shard_dir.join(format!("shard-{k}.report.json"));
    let mut cmds: Vec<Vec<String>> = Vec::with_capacity(shards);
    for (k, m) in shard::shard_manifests(&spec, shards).iter().enumerate() {
        let mpath = shard_dir.join(format!("shard-{k}.manifest.json"));
        if let Err(e) = shard::write_json_file(&mpath, m) {
            eprintln!("{e:#}");
            return 1;
        }
        let mut argv = base.clone();
        argv.push("sweep-worker".to_string());
        argv.push("--manifest".to_string());
        argv.push(mpath.display().to_string());
        argv.push("--out".to_string());
        argv.push(report_path(k).display().to_string());
        match &cache {
            Some(c) => {
                argv.push("--cache-dir".to_string());
                argv.push(c.dir().display().to_string());
                if let Some(mb) = args.get("cache-max-mb") {
                    argv.push("--cache-max-mb".to_string());
                    argv.push(mb.to_string());
                }
            }
            None => argv.push("--no-cache".to_string()),
        }
        if args.has_flag("full-ledger") {
            argv.push("--full-ledger".to_string());
        }
        // Chaos specs given via the CLI (rather than TPUFLEET_FAULTS,
        // which subprocesses inherit) must reach the workers explicitly.
        if let Some(spec) = args.get("inject-faults") {
            argv.push("--inject-faults".to_string());
            argv.push(spec.to_string());
        }
        cmds.push(argv);
    }

    eprintln!(
        "sweeping {total} variants across {shards} shard processes (cache {})...",
        match &cache {
            Some(c) => c.dir().display().to_string(),
            None => "off".to_string(),
        }
    );
    let t0 = std::time::Instant::now();
    // Progress counters are PER SHARD so a retried shard's replayed
    // progress lines (its finished variants stream back as cache hits)
    // reset instead of double-counting; the displayed totals are sums.
    let done: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
    let hits: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
    let sum = |v: &[AtomicUsize]| -> usize { v.iter().map(|c| c.load(Ordering::Relaxed)).sum() };
    // Workers speak the per-variant progress protocol on stdout; anything
    // else they print is forwarded tagged with the shard index. The
    // aggregate ETA mirrors the serial path: rate from simulated variants
    // only, so a partially warm cache doesn't fake a wildly optimistic
    // finish time. A dead worker is re-spawned up to `--retries` times
    // with bounded deterministic backoff; it resumes from the shared
    // cache, so the merged report stays byte-identical to a clean run.
    let outcomes = subproc::run_supervised(
        &cmds,
        retries,
        |k, line| match shard::parse_progress_line(line) {
            Some((cached, name)) => {
                done[k].fetch_add(1, Ordering::Relaxed);
                if cached {
                    hits[k].fetch_add(1, Ordering::Relaxed);
                }
                if progress {
                    let d = sum(&done);
                    let h = sum(&hits);
                    let elapsed = t0.elapsed().as_secs_f64();
                    let simmed = d.saturating_sub(h);
                    let eta = if simmed > 0 {
                        elapsed / simmed as f64 * total.saturating_sub(d) as f64
                    } else {
                        0.0
                    };
                    eprintln!(
                        "progress: {d}/{total} ({:.0}%) elapsed {elapsed:.1}s \
                         eta {eta:.1}s ({h} cached) [shard {k}] {name}",
                        d as f64 / total.max(1) as f64 * 100.0
                    );
                }
            }
            None => eprintln!("[shard {k}] {line}"),
        },
        |k, attempt, failure, delay| {
            // The dead child's stdout is drained before this fires, so
            // zeroing the shard's counters races nothing.
            done[k].store(0, Ordering::Relaxed);
            hits[k].store(0, Ordering::Relaxed);
            eprintln!(
                "shard {k} attempt {} failed ({failure}); respawning in {}ms \
                 (attempt {} of {}, resuming from the shared cache)",
                attempt + 1,
                delay.as_millis(),
                attempt + 2,
                retries + 1,
            );
        },
    );
    let mut failed = false;
    for (k, oc) in outcomes.iter().enumerate() {
        match &oc.result {
            Ok(s) if s.success() => {
                if oc.attempts > 1 {
                    eprintln!("shard {k} recovered on attempt {} of {}", oc.attempts, retries + 1);
                }
            }
            _ => {
                let hint = if cache.is_some() {
                    "finished variants persist in the cache — re-run the same \
                     command to resume"
                } else {
                    "cache is off (--no-cache), so a re-run recomputes its variants"
                };
                let err = shard::ShardFailure {
                    shard: k,
                    attempts: oc.attempts,
                    statuses: oc.failures.clone(),
                };
                eprintln!("{err}; {hint}");
                failed = true;
            }
        }
    }
    if failed {
        return 1;
    }
    let mut reports = Vec::with_capacity(shards);
    for k in 0..shards {
        match shard::read_json_file(&report_path(k)) {
            Ok(j) => reports.push(j),
            Err(e) => {
                eprintln!("{e:#}");
                return 1;
            }
        }
    }
    let rows = match shard::merge_shard_reports(&reports, total) {
        Ok(rows) => rows,
        Err(e) => {
            eprintln!("merging shard reports failed: {e:#}");
            return 1;
        }
    };
    let write_merged = || -> std::io::Result<()> {
        let file = std::fs::File::create(&out_path)?;
        let mut out = std::io::BufWriter::new(file);
        shard::write_report_header(&mut out, &spec_json)?;
        for (i, r) in rows.iter().enumerate() {
            shard::write_report_row(&mut out, i, &r.row)?;
        }
        shard::write_report_footer(&mut out)?;
        out.flush()
    };
    if let Err(e) = write_merged() {
        eprintln!("writing {out_path} failed: {e}");
        return 1;
    }
    // Same stdout summary table as the serial path, rebuilt from the
    // merged rows (the bottleneck layer comes from the row's embedded
    // attribution section).
    let mut table = report::Table::new(
        "Scenario sweep — fleet goodputs per variant",
        &[
            "variant",
            "SG",
            "RG",
            "PG",
            "MPG",
            "completed",
            "preempt",
            "failures",
            "bottleneck",
            "src",
        ],
    );
    for r in &rows {
        let f = |key: &str| r.row.get(key).as_f64().unwrap_or(f64::NAN);
        let u = |key: &str| r.row.get(key).as_u64().unwrap_or(0);
        table.row(vec![
            r.row.get("name").as_str().unwrap_or("?").to_string(),
            format!("{:.3}", f("sg")),
            format!("{:.3}", f("rg")),
            format!("{:.3}", f("pg")),
            format!("{:.3}", f("mpg")),
            u("completed_jobs").to_string(),
            u("preemptions").to_string(),
            u("failures_injected").to_string(),
            r.row
                .get("attribution")
                .get("bottleneck")
                .as_str()
                .unwrap_or("?")
                .to_string(),
            if r.cached { "cache".to_string() } else { "sim".to_string() },
        ]);
    }
    println!("{}", table.to_ascii());
    let cache_hits = rows.iter().filter(|r| r.cached).count();
    eprintln!(
        "done in {:.2}s ({cache_hits}/{total} cache hits across {shards} shards); \
         wrote {out_path}",
        t0.elapsed().as_secs_f64()
    );
    warn_corrupt_entries(&cache);
    if args.has_flag("cache-stats") {
        match &cache {
            Some(c) => print_cache_stats(c, cache_hits as u64, (total - cache_hits) as u64),
            None => eprintln!("cache stats: cache disabled (--no-cache)"),
        }
    }
    let _ = std::fs::remove_dir_all(&shard_dir);
    0
}

/// Internal: run one shard manifest and write the shard report the
/// coordinator merges. Per-variant progress goes to stdout in the
/// `sim::shard` line protocol (flushed per line — it feeds a pipe).
fn cmd_sweep_worker(args: &Args) -> i32 {
    use std::io::Write;
    use tpufleet::util::Json;

    const WORKER_USAGE: &str =
        "usage: tpufleet sweep-worker --manifest FILE --out FILE \
         [--cache-dir DIR | --no-cache] [--cache-max-mb N] [--full-ledger] \
         [--inject-faults SPEC]";
    let known = [
        "manifest",
        "out",
        "cache-dir",
        "no-cache",
        "cache-max-mb",
        "full-ledger",
        "inject-faults",
    ];
    if let Some(code) = check_flags(args, "sweep-worker", &known) {
        return code;
    }
    if let Some(spec) = args.get("inject-faults") {
        tpufleet::util::fault::install(spec);
    }
    let Some(manifest_path) = args.get("manifest") else {
        eprintln!("{WORKER_USAGE}");
        return 2;
    };
    let Some(out_path) = args.get("out") else {
        eprintln!("{WORKER_USAGE}");
        return 2;
    };
    let cache = match sweep_cache_from_args(args) {
        Ok(cache) => cache,
        Err(code) => return code,
    };
    let task = match shard::read_json_file(std::path::Path::new(manifest_path))
        .and_then(|j| shard::parse_manifest(&j))
    {
        Ok(task) => task,
        Err(e) => {
            eprintln!("sweep-worker: {e:#}");
            return 2;
        }
    };
    let indices: Vec<usize> = task.variants.iter().map(|(i, _)| *i).collect();
    let mut rows: Vec<(usize, bool, Json)> = Vec::new();
    let stdout = std::io::stdout();
    let mode = sweep_ledger_mode(args);
    SweepRunner::run_streaming_summaries_with_mode(task.spec(), cache.as_ref(), mode, |s| {
        let k = rows.len();
        rows.push((indices[k], s.cached, shard::summary_row_json(&s)));
        let mut lock = stdout.lock();
        let _ = writeln!(lock, "{}", shard::progress_line(s.cached, &s.name));
        let _ = lock.flush();
        // Chaos site: die abruptly after a completed variant (subsumes
        // the legacy TPUFLEET_SHARD_FAIL_AFTER hook). Finished variants
        // are already in the shared cache, so a supervisor re-spawn (or
        // an operator re-run) resumes instead of recomputing.
        if tpufleet::util::fault::fire(tpufleet::util::fault::Site::ShardWorkerExit) {
            std::process::exit(tpufleet::util::fault::INJECTED_EXIT_CODE);
        }
    });
    let report = shard::shard_report(&task, &rows);
    if let Err(e) = shard::write_json_file(std::path::Path::new(out_path), &report) {
        eprintln!("sweep-worker: {e:#}");
        return 1;
    }
    0
}

fn cmd_trace(args: &Args) -> i32 {
    use tpufleet::metrics::AttributionReport;
    use tpufleet::workload::{trace, GeneratorConfig, WorkloadGenerator};
    match args.positional.first().map(|s| s.as_str()) {
        Some("generate") => {
            if let Some(code) = check_flags(args, "trace generate", &["hours", "seed", "out"]) {
                return code;
            }
            // `--out FILE` is the cross-subcommand spelling; the bare
            // positional form still works.
            let out = args
                .get("out")
                .map(str::to_string)
                .or_else(|| args.positional.get(1).cloned());
            let Some(out) = out else {
                eprintln!("usage: tpufleet trace generate [<out.json>] [--hours H] [--out FILE]");
                return 2;
            };
            let hours = args.get_f64("hours", 24.0);
            let cfg = GeneratorConfig {
                seed: args.get_u64("seed", 42),
                duration_s: hours * 3600.0,
                ..Default::default()
            };
            let jobs = WorkloadGenerator::new(cfg).trace();
            if let Err(e) = trace::save(&jobs, std::path::Path::new(&out)) {
                eprintln!("trace save failed: {e:#}");
                return 1;
            }
            eprintln!("wrote {} jobs to {out}", jobs.len());
            0
        }
        Some("replay") => {
            let known = ["days", "seed", "windowed", "out"];
            if let Some(code) = check_flags(args, "trace replay", &known) {
                return code;
            }
            let Some(input) = args.positional.get(1) else {
                eprintln!("usage: tpufleet trace replay <in.json> [--days N] [--windowed]");
                return 2;
            };
            let jobs = match trace::load(std::path::Path::new(input)) {
                Ok(j) => j,
                Err(e) => {
                    eprintln!("trace load failed: {e:#}");
                    return 1;
                }
            };
            let horizon = jobs.iter().map(|j| j.arrival_s).fold(0.0, f64::max) / 86400.0;
            let days = args.get_f64("days", (horizon + 1.0).ceil());
            let mut cfg = SimConfig {
                seed: args.get_u64("seed", 42),
                duration_s: days * 24.0 * 3600.0,
                ..Default::default()
            };
            let windowed = args.has_flag("windowed");
            eprintln!(
                "replaying {} jobs over {days} days ({} accounting)...",
                jobs.len(),
                if windowed { "windowed" } else { "full-span" }
            );
            cfg.source = JobSource::materialized(jobs);
            let mode = if windowed {
                tpufleet::sim::sweep::summary_ledger_mode()
            } else {
                LedgerMode::Full
            };
            let mut sim = Simulation::new(cfg.clone()).ledger_mode(mode);
            let res = sim.run();
            eprintln!("{res:?}");
            // The segmented summary needs retained spans; the fleet MPG
            // line and the --out report come from `fleet_goodput`, which
            // is bit-identical across accounting modes.
            if !windowed {
                print!("{}", figures::mpg_summary(&sim.ledger, 0.0, cfg.duration_s).to_ascii());
            }
            let fleet = sim.fleet_goodput();
            println!(
                "fleet MPG = SG {:.3} x RG {:.3} x PG {:.3} = {:.4}",
                fleet.sg,
                fleet.rg,
                fleet.pg,
                fleet.mpg()
            );
            if let Some(out) = args.get("out") {
                let att = AttributionReport::of(&fleet);
                if let Err(e) = std::fs::write(out, att.to_json().to_string_pretty()) {
                    eprintln!("writing {out} failed: {e}");
                    return 1;
                }
                eprintln!("wrote {out}");
            }
            0
        }
        _ => {
            eprintln!("usage: tpufleet trace <generate|replay> ...");
            2
        }
    }
}

/// Flag vocabulary for `monitor` stream ingest (the `record` subaction
/// declares its own).
const MONITOR_FLAGS: [&str; 19] = [
    "in",
    "out",
    "width-s",
    "ring-windows",
    "snapshot-every",
    "batch",
    "follow",
    "progress",
    "merge",
    "stream-ids",
    "reorder-cap",
    "listen",
    "series-out",
    "checkpoint",
    "checkpoint-keep",
    "resume",
    "no-auto-resume",
    "quarantine",
    "inject-faults",
];

/// Per-line `monitor` state shared by the stdin, file, and `--follow`
/// readers: parse -> validate -> count -> account. Streaming mode folds
/// each event into the [`MonitorLedger`] as it arrives; `--batch`
/// retains the parsed events and replays them through the batch
/// [`WindowedLedger`] at the end, folding the watermark through the
/// same `f64::max` chain the monitor runs so both modes hand
/// [`snapshot_json`] an identical horizon — and therefore emit
/// byte-identical snapshots (the CI cross-mode `cmp` gate).
struct MonitorIngest {
    ml: MonitorLedger,
    validator: proto::Validator,
    stats: StreamStats,
    batch: bool,
    /// Batch mode only: the replay tape.
    events: Vec<proto::Event>,
    /// Batch mode only: max event end-time seen so far.
    batch_watermark: f64,
    snapshot_every: Option<f64>,
    last_emit: f64,
    out: Option<String>,
    progress: bool,
    lines: u64,
    /// Parsed events fed so far (the `/streams` telemetry row).
    event_count: u64,
    /// Stream id for `/streams`: the input's framing-header id, or its
    /// path, or "stdin".
    stream_name: String,
    /// Streaming mode only: `--series-out` rolling-series JSON target.
    series_out: Option<String>,
    /// Streaming mode only: the `--listen` dashboard's render cache;
    /// refreshed whenever a snapshot is emitted.
    dash: Option<http::SharedDash>,
    /// Streaming mode only: `--checkpoint FILE`, written atomically at
    /// every snapshot emission so a killed monitor can `--resume`.
    ckpt: Option<String>,
    /// `--checkpoint-keep K`: checkpoint generations retained per write
    /// (`FILE`, `FILE.1`, …); 1 (the default) keeps only the latest.
    ckpt_keep: usize,
}

impl MonitorIngest {
    /// Feed one raw stream line; `Ok(true)` once the `end` line lands.
    fn feed(&mut self, raw: &str) -> Result<bool, String> {
        use proto::Event;
        self.lines += 1;
        let ev = match Event::parse(raw) {
            Ok(Some(ev)) => ev,
            Ok(None) => return Ok(false),
            Err(e) => return Err(format!("line {}: {e}", self.lines)),
        };
        if let Err(e) = self.validator.check(&ev) {
            return Err(format!("line {}: {e}", self.lines));
        }
        self.event_count += 1;
        match ev {
            Event::Span { .. } => self.stats.spans += 1,
            Event::Pg { .. } => self.stats.pg_samples += 1,
            Event::Capacity { .. } => self.stats.cap_events += 1,
            Event::Job(_) | Event::End => {}
        }
        self.stats.jobs = self.validator.job_count();
        let done = matches!(ev, Event::End);
        if self.batch {
            if let Some(t) = ev.end_time() {
                self.batch_watermark = self.batch_watermark.max(t);
            }
            self.events.push(ev);
            return Ok(done);
        }
        self.ml.ingest(&ev);
        if let Some(every) = self.snapshot_every {
            if self.ml.watermark_s() - self.last_emit >= every {
                self.last_emit = self.ml.watermark_s();
                self.emit(false)?;
                self.write_ckpt()?;
                // Chaos site: die right after a completed snapshot +
                // checkpoint, the worst honest crash point (anything
                // later is covered by the checkpoint just written).
                if tpufleet::util::fault::fire(tpufleet::util::fault::Site::MonitorExit) {
                    std::process::exit(tpufleet::util::fault::INJECTED_EXIT_CODE);
                }
            }
        }
        Ok(done)
    }

    /// Write the crash-safe checkpoint (no-op without `--checkpoint`):
    /// ledger + validator state, raw lines consumed, and the emit
    /// watermark — everything `--resume` needs to continue the exact
    /// addition chains mid-stream.
    fn write_ckpt(&self) -> Result<(), String> {
        use tpufleet::util::Json;
        let Some(path) = &self.ckpt else {
            return Ok(());
        };
        let Json::Obj(mut doc) = ckpt::header_json() else {
            unreachable!("checkpoint header is an object")
        };
        doc.insert("mode".to_string(), Json::str("single"));
        doc.insert("lines".to_string(), Json::num(self.lines as f64));
        doc.insert("event_count".to_string(), Json::num(self.event_count as f64));
        doc.insert("last_emit".to_string(), Json::f64b(self.last_emit));
        doc.insert("stream_name".to_string(), Json::str(&self.stream_name));
        doc.insert("ledger".to_string(), self.ml.ckpt_json());
        doc.insert("validator".to_string(), self.validator.ckpt_json());
        doc.insert(
            "stats".to_string(),
            Json::obj(vec![
                ("jobs", Json::num(self.stats.jobs as f64)),
                ("spans", Json::num(self.stats.spans as f64)),
                ("pg_samples", Json::num(self.stats.pg_samples as f64)),
                ("cap_events", Json::num(self.stats.cap_events as f64)),
            ]),
        );
        ckpt::write_rotating(std::path::Path::new(path), &Json::Obj(doc), self.ckpt_keep)
            .map_err(|e| format!("writing checkpoint {path} failed: {e}"))
    }

    /// Restore ingest state from a `--resume` checkpoint document
    /// (version header already checked). Returns the number of raw
    /// input lines the dead process had consumed — the caller skips
    /// exactly that many before feeding.
    fn restore(&mut self, doc: &tpufleet::util::Json) -> Result<u64, String> {
        use tpufleet::util::Json;
        if doc.get("mode").as_str() != Some("single") {
            return Err("checkpoint was taken by a --merge monitor; add --merge".to_string());
        }
        let ml = MonitorLedger::from_ckpt(doc.get("ledger"))?;
        if ml.width_s().to_bits() != self.ml.width_s().to_bits()
            || ml.ring_windows() != self.ml.ring_windows()
        {
            return Err(format!(
                "checkpoint was taken at --width-s {} --ring-windows {}; \
                 resume with the same values (got --width-s {} --ring-windows {})",
                ml.width_s(),
                ml.ring_windows(),
                self.ml.width_s(),
                self.ml.ring_windows()
            ));
        }
        self.ml = ml;
        self.validator = proto::Validator::from_ckpt(doc.get("validator"))?;
        let lines = doc.get("lines").as_u64().ok_or("checkpoint: bad `lines`")?;
        self.lines = lines;
        self.event_count =
            doc.get("event_count").as_u64().ok_or("checkpoint: bad `event_count`")?;
        self.last_emit = doc.get("last_emit").as_f64b().ok_or("checkpoint: bad `last_emit`")?;
        let stats = doc.get("stats");
        self.stats = StreamStats {
            jobs: stats.get("jobs").as_u64().ok_or("checkpoint: bad `stats`")? as usize,
            spans: stats.get("spans").as_u64().ok_or("checkpoint: bad `stats`")?,
            pg_samples: stats.get("pg_samples").as_u64().ok_or("checkpoint: bad `stats`")?,
            cap_events: stats.get("cap_events").as_u64().ok_or("checkpoint: bad `stats`")?,
        };
        if let Some(name) = doc.get("stream_name").as_str() {
            self.stream_name = name.to_string();
        }
        Ok(lines)
    }

    /// The snapshot document at the current watermark, rendered. The
    /// `--out` file and the dashboard's `GET /snapshot` both serve this
    /// exact string — the byte-identity the CI smoke `cmp`s.
    fn snapshot_text(&self, is_final: bool) -> String {
        let doc = if self.batch {
            let mut win = WindowedLedger::new(self.batch_watermark, self.ml.width_s());
            for ev in &self.events {
                match *ev {
                    proto::Event::Capacity { t, chips } => win.set_capacity(t, chips),
                    proto::Event::Job(ref m) => win.ensure_job(m.clone()),
                    proto::Event::Span { id, t0, t1, chips, class, layer } => {
                        win.add_span(id, t0, t1, chips, class, layer)
                    }
                    proto::Event::Pg { id, t0, t1, chips, pg } => {
                        win.add_pg_sample(id, t0, t1, chips, pg)
                    }
                    proto::Event::End => {}
                }
            }
            let report = win.report(|_| true);
            snapshot_json(&report, self.batch_watermark, win.width_s(), &self.stats, is_final)
        } else {
            let report = self.ml.report(|_| true);
            snapshot_json(&report, self.ml.watermark_s(), self.ml.width_s(), &self.stats, is_final)
        };
        format!("{}\n", doc.to_string_pretty())
    }

    /// The `GET /series` body: the rolling ring as per-window reports.
    fn series_text(&self) -> String {
        let series = self.ml.recent_series(|_| true);
        format!(
            "{}\n",
            series_json(&series, self.ml.width_s(), self.ml.watermark_s()).to_string_pretty()
        )
    }

    /// The `GET /streams` body: a single-stream merger's telemetry shape
    /// (one row, zero lag — the cross-stream watermark IS the watermark).
    fn streams_text(&self, is_final: bool) -> String {
        let info = merge::StreamInfo {
            name: self.stream_name.clone(),
            watermark_s: self.ml.watermark_s(),
            lag_s: 0.0,
            finished: is_final,
            quarantined: None,
            buffered: 0,
            peak_buffered: 0,
            events: self.event_count,
            jobs: self.stats.jobs as u64,
            spans: self.stats.spans,
            pg_samples: self.stats.pg_samples,
            cap_events: self.stats.cap_events,
            chips: self.ml.current_capacity_chips(),
        };
        let doc = merge::streams_doc(self.ml.watermark_s(), &[info]);
        format!("{}\n", doc.to_string_pretty())
    }

    /// Re-render the dashboard's endpoint bodies (no file writes) —
    /// called once up front so `--listen` serves a valid (empty-stream)
    /// snapshot before the first emit.
    fn dash_refresh(&self, is_final: bool) {
        if let Some(dash) = &self.dash {
            let mut d = dash.lock().expect("dashboard state poisoned");
            d.snapshot = self.snapshot_text(is_final);
            d.series = self.series_text();
            d.streams = self.streams_text(is_final);
        }
    }

    /// Write one snapshot to `--out` (overwriting) or stdout, plus the
    /// `--series-out` document and the dashboard cache where configured.
    fn emit(&self, is_final: bool) -> Result<(), String> {
        let text = self.snapshot_text(is_final);
        match &self.out {
            Some(path) => {
                std::fs::write(path, &text).map_err(|e| format!("writing {path} failed: {e}"))?;
            }
            None => print!("{text}"),
        }
        if let Some(path) = &self.series_out {
            std::fs::write(path, self.series_text())
                .map_err(|e| format!("writing {path} failed: {e}"))?;
        }
        self.dash_refresh(is_final);
        if self.progress {
            if self.batch {
                eprintln!(
                    "monitor: {} lines, watermark {:.1}s (batch replay)",
                    self.lines, self.batch_watermark
                );
            } else {
                eprintln!(
                    "monitor: t={:.1}s jobs={} live-jobs={} cells={} peak-cells={} evicted={}",
                    self.ml.watermark_s(),
                    self.ml.job_count(),
                    self.ml.live_job_count(),
                    self.ml.live_cells(),
                    self.ml.peak_cells(),
                    self.ml.evicted_cells()
                );
            }
        }
        Ok(())
    }
}

/// Tail `path` like `tail -f`, feeding complete lines as the writer
/// lands them, until the `end` line (or a stream error). A partial
/// trailing line is held until the writer finishes it; `skip_lines`
/// complete lines are discarded first (`--resume` replays past what the
/// dead process already ingested).
fn monitor_follow(path: &str, ing: &mut MonitorIngest, skip_lines: u64) -> Result<(), String> {
    let mut reader = TailReader::open(path, true)?;
    let mut skip = skip_lines;
    loop {
        match reader.next_line()? {
            None => std::thread::sleep(std::time::Duration::from_millis(200)),
            Some(_) if skip > 0 => skip -= 1,
            Some(line) => {
                if ing.feed(&line)? {
                    return Ok(());
                }
            }
        }
    }
}

fn cmd_monitor(args: &Args) -> i32 {
    if args.positional.first().map(|s| s.as_str()) == Some("record") {
        return cmd_monitor_record(args);
    }
    if !args.positional.is_empty() {
        eprintln!("usage: tpufleet monitor [record] [options]  (see `tpufleet help`)");
        return 2;
    }
    if let Some(code) = check_flags(args, "monitor", &MONITOR_FLAGS) {
        return code;
    }
    if let Some(spec) = args.get("inject-faults") {
        tpufleet::util::fault::install(spec);
    }
    let width_s = args.get_f64("width-s", 3600.0);
    if !width_s.is_finite() || width_s <= 0.0 {
        eprintln!("monitor: --width-s must be a positive number of seconds");
        return 2;
    }
    let ring_windows = args.get_usize("ring-windows", 48);
    if ring_windows == 0 {
        eprintln!("monitor: --ring-windows must be at least 1");
        return 2;
    }
    let batch = args.has_flag("batch");
    let follow = args.has_flag("follow");
    if batch && follow {
        eprintln!("monitor: --batch and --follow are mutually exclusive");
        return 2;
    }
    if follow && args.get("in").is_none() {
        eprintln!("monitor: --follow requires --in FILE (stdin cannot be tailed)");
        return 2;
    }
    let snapshot_every = match args.get("snapshot-every") {
        None => None,
        Some(s) => match s.parse::<f64>() {
            Ok(v) if v.is_finite() && v > 0.0 => Some(v),
            _ => {
                eprintln!("monitor: bad --snapshot-every `{s}` (need seconds > 0)");
                return 2;
            }
        },
    };
    if batch && snapshot_every.is_some() {
        eprintln!("monitor: --snapshot-every requires streaming mode (drop --batch)");
        return 2;
    }
    if batch && (args.get("listen").is_some() || args.get("series-out").is_some()) {
        eprintln!("monitor: --listen/--series-out require streaming mode (drop --batch)");
        return 2;
    }
    let merge_mode = args.has_flag("merge");
    if !merge_mode && (args.get("stream-ids").is_some() || args.get("reorder-cap").is_some()) {
        eprintln!("monitor: --stream-ids/--reorder-cap only apply with --merge");
        return 2;
    }
    let quarantine = args.has_flag("quarantine");
    if quarantine && !merge_mode {
        eprintln!("monitor: --quarantine only applies with --merge (a single bad stream IS the run)");
        return 2;
    }
    let ckpt_path = args.get("checkpoint").map(str::to_string);
    let mut resume_path = args.get("resume").map(str::to_string);
    if batch && (ckpt_path.is_some() || resume_path.is_some()) {
        eprintln!("monitor: --checkpoint/--resume require streaming mode (drop --batch)");
        return 2;
    }
    let ckpt_keep = args.get_usize("checkpoint-keep", 1);
    if args.get("checkpoint-keep").is_some() && ckpt_path.is_none() {
        eprintln!("monitor: --checkpoint-keep only applies with --checkpoint FILE");
        return 2;
    }
    if ckpt_keep == 0 {
        eprintln!("monitor: --checkpoint-keep must be at least 1");
        return 2;
    }
    if args.has_flag("no-auto-resume") && ckpt_path.is_none() {
        eprintln!("monitor: --no-auto-resume only applies with --checkpoint FILE");
        return 2;
    }
    // Auto-resume: `--checkpoint FILE` with no explicit `--resume` picks
    // up a compatible checkpoint already sitting at FILE (a restarted
    // follower continues where its predecessor died). Compatibility is
    // enforced by the same version/mode/shape checks as explicit
    // `--resume`; an incompatible file is a hard error rather than a
    // silent restart, and `--no-auto-resume` opts out entirely.
    if resume_path.is_none() && !args.has_flag("no-auto-resume") {
        if let Some(path) = &ckpt_path {
            if std::path::Path::new(path).exists() {
                eprintln!(
                    "monitor: auto-resuming from existing checkpoint {path} \
                     (disable with --no-auto-resume)"
                );
                resume_path = Some(path.clone());
            }
        }
    }
    let dash = match args.get("listen") {
        None => None,
        Some(addr) => {
            let listener = match std::net::TcpListener::bind(addr) {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("monitor: binding {addr} failed: {e}");
                    return 1;
                }
            };
            match listener.local_addr() {
                Ok(a) => eprintln!("monitor: dashboard listening on http://{a}"),
                Err(_) => eprintln!("monitor: dashboard listening on http://{addr}"),
            }
            let dash = http::shared(http::DashState::default());
            http::serve(listener, dash.clone());
            Some(dash)
        }
    };
    if merge_mode {
        let opts = MergeOpts {
            width_s,
            ring_windows,
            batch,
            follow,
            snapshot_every,
            dash,
            ckpt: ckpt_path,
            ckpt_keep,
            resume: resume_path,
            quarantine,
        };
        return cmd_monitor_merge(args, opts);
    }
    let stream_name = match args.get("in") {
        Some(path) if !follow => match stream_id_of(path) {
            Ok(Some(id)) => id,
            Ok(None) => path.to_string(),
            Err(e) => {
                eprintln!("monitor: {e}");
                return 1;
            }
        },
        // Follow mode: the file may not have its header yet.
        Some(path) => path.to_string(),
        None => "stdin".to_string(),
    };
    let mut ing = MonitorIngest {
        ml: MonitorLedger::new(width_s, ring_windows),
        validator: proto::Validator::labeled(&stream_name),
        stats: StreamStats::default(),
        batch,
        events: Vec::new(),
        batch_watermark: 0.0,
        snapshot_every,
        last_emit: 0.0,
        out: args.get("out").map(str::to_string),
        progress: args.has_flag("progress"),
        lines: 0,
        event_count: 0,
        stream_name,
        series_out: args.get("series-out").map(str::to_string),
        dash,
        ckpt: ckpt_path,
        ckpt_keep,
    };
    let mut skip_lines = 0u64;
    if let Some(path) = &resume_path {
        let restored = ckpt::read(std::path::Path::new(path)).and_then(|doc| ing.restore(&doc));
        match restored {
            Ok(n) => skip_lines = n,
            Err(e) => {
                eprintln!("monitor: {e}");
                return 1;
            }
        }
        eprintln!(
            "monitor: resumed from {path} at line {skip_lines}, watermark {:.1}s",
            ing.ml.watermark_s()
        );
    }
    ing.dash_refresh(false);
    let fed = if follow {
        monitor_follow(args.get("in").expect("checked above"), &mut ing, skip_lines)
    } else {
        let text = match args.get("in") {
            Some(path) => {
                std::fs::read_to_string(path).map_err(|e| format!("reading {path} failed: {e}"))
            }
            None => {
                let stdin = std::io::stdin();
                let mut s = String::new();
                std::io::Read::read_to_string(&mut stdin.lock(), &mut s)
                    .map(|_| s)
                    .map_err(|e| format!("reading stdin failed: {e}"))
            }
        };
        text.and_then(|text| {
            for line in text.lines().skip(skip_lines as usize) {
                if ing.feed(line)? {
                    break;
                }
            }
            Ok(())
        })
    };
    let done = fed.and_then(|()| ing.emit(true)).and_then(|()| ing.write_ckpt());
    if let Err(e) = done {
        eprintln!("monitor: {e}");
        return 1;
    }
    if let Some(out) = args.get("out") {
        eprintln!("wrote {out}");
    }
    0
}

/// Read the stream-framing header id from a file's first line, if any.
/// Errors only on a stream recorded by a FUTURE protocol version.
fn stream_id_of(path: &str) -> Result<Option<String>, String> {
    use std::io::BufRead as _;
    let file = std::fs::File::open(path).map_err(|e| format!("opening {path} failed: {e}"))?;
    let mut first = String::new();
    std::io::BufReader::new(file)
        .read_line(&mut first)
        .map_err(|e| format!("reading {path} failed: {e}"))?;
    match proto::parse_stream_header(&first) {
        Some((v, _)) if v > proto::PROTO_VERSION => Err(format!(
            "{path} is a v{v} stream; this build reads up to v{}",
            proto::PROTO_VERSION
        )),
        Some((_, id)) => Ok(Some(id.to_string())),
        None => Ok(None),
    }
}

/// Incremental line reader shared by the single-stream `--follow`, the
/// merged one-shot, and the merged `--follow` paths: returns complete
/// lines as they become available, holding a partial trailing line until
/// the writer finishes it. In one-shot mode EOF flushes any final
/// unterminated line and marks the reader done; in follow mode EOF just
/// means "nothing yet".
///
/// Reads are BYTE-based (`read_until`), not `String::read_line`: a
/// writer caught mid-way through a multi-byte UTF-8 character must look
/// like "line not finished yet", not a stream error — `read_line` would
/// fail with `InvalidData` AND lose the bytes it had consumed. Only a
/// complete (newline-terminated) line is converted, lossily: the
/// protocol is ASCII, so replacement characters only ever appear in
/// corrupt lines, which then fail `Event::parse` with a line-numbered
/// error (or quarantine, under `--quarantine`).
struct TailReader {
    path: String,
    reader: std::io::BufReader<std::fs::File>,
    pending: Vec<u8>,
    follow: bool,
    eof: bool,
}

impl TailReader {
    fn open(path: &str, follow: bool) -> Result<TailReader, String> {
        let file = std::fs::File::open(path).map_err(|e| format!("opening {path} failed: {e}"))?;
        Ok(TailReader {
            path: path.to_string(),
            reader: std::io::BufReader::new(file),
            pending: Vec::new(),
            follow,
            eof: false,
        })
    }

    /// One read attempt; `Ok(None)` means no complete line is available
    /// right now (check `eof` to distinguish "done" from "not yet").
    fn next_line(&mut self) -> Result<Option<String>, String> {
        use std::io::BufRead as _;
        let n = self
            .reader
            .read_until(b'\n', &mut self.pending)
            .map_err(|e| format!("reading {} failed: {e}", self.path))?;
        let take = |pending: &mut Vec<u8>| {
            String::from_utf8_lossy(&std::mem::take(pending)).into_owned()
        };
        if n == 0 {
            if self.follow {
                return Ok(None);
            }
            self.eof = true;
            if self.pending.is_empty() {
                return Ok(None);
            }
            return Ok(Some(take(&mut self.pending)));
        }
        if self.pending.last() != Some(&b'\n') {
            return Ok(None);
        }
        Ok(Some(take(&mut self.pending)))
    }
}

/// Where `monitor --merge` renders to: `--out`/stdout, `--series-out`,
/// and the `--listen` dashboard cache.
struct MergedSinks {
    out: Option<String>,
    series_out: Option<String>,
    dash: Option<http::SharedDash>,
    progress: bool,
}

/// Write the merged snapshot (and series / dashboard documents) at the
/// merged ledger's current watermark. Stream totals come from the
/// ledger's own counters, so the live pump and the batch interleave
/// replay — which ingest the identical event sequence — render
/// byte-identical snapshots. `dash_only` refreshes the dashboard cache
/// without touching `--out`/stdout (the pre-ingest priming pass).
fn emit_merged(
    ml: &MonitorLedger,
    merger: &merge::StreamMerger,
    sinks: &MergedSinks,
    dash_only: bool,
    is_final: bool,
) -> Result<(), String> {
    let stats = StreamStats {
        jobs: ml.job_count(),
        spans: ml.span_count(),
        pg_samples: ml.pg_count(),
        cap_events: ml.cap_events(),
    };
    let report = ml.report(|_| true);
    let doc = snapshot_json(&report, ml.watermark_s(), ml.width_s(), &stats, is_final);
    let text = format!("{}\n", doc.to_string_pretty());
    if !dash_only {
        match &sinks.out {
            Some(path) => {
                std::fs::write(path, &text).map_err(|e| format!("writing {path} failed: {e}"))?;
            }
            None => print!("{text}"),
        }
    }
    let series_text = if sinks.series_out.is_some() || sinks.dash.is_some() {
        let series = ml.recent_series(|_| true);
        format!("{}\n", series_json(&series, ml.width_s(), ml.watermark_s()).to_string_pretty())
    } else {
        String::new()
    };
    if !dash_only {
        if let Some(path) = &sinks.series_out {
            std::fs::write(path, &series_text)
                .map_err(|e| format!("writing {path} failed: {e}"))?;
        }
    }
    if let Some(dash) = &sinks.dash {
        let streams_text = format!("{}\n", merger.streams_json().to_string_pretty());
        let mut d = dash.lock().expect("dashboard state poisoned");
        d.snapshot = text.clone();
        d.series = series_text;
        d.streams = streams_text;
    }
    if sinks.progress && !dash_only {
        eprintln!(
            "monitor: merged {} streams t={:.1}s cross-watermark={:.1}s jobs={} cells={}",
            merger.stream_count(),
            ml.watermark_s(),
            merger.cross_watermark_s(),
            ml.job_count(),
            ml.live_cells()
        );
    }
    Ok(())
}

/// `monitor --merge` options resolved by [`cmd_monitor`] (bundled so the
/// merge entrypoint keeps a readable signature).
struct MergeOpts {
    width_s: f64,
    ring_windows: usize,
    batch: bool,
    follow: bool,
    snapshot_every: Option<f64>,
    dash: Option<http::SharedDash>,
    ckpt: Option<String>,
    ckpt_keep: usize,
    resume: Option<String>,
    quarantine: bool,
}

/// State restored from a `--merge` checkpoint: everything the dead
/// process held, plus how many raw lines of each input it had consumed.
struct MergeResume {
    merger: merge::StreamMerger,
    ml: MonitorLedger,
    validators: Vec<proto::Validator>,
    lines: Vec<u64>,
    last_emit: f64,
}

/// Write the merged-monitor checkpoint: ledger + merger + per-stream
/// validator state and consumed-line counts, under the version header.
fn write_merge_ckpt(
    path: &str,
    keep: usize,
    ml: &MonitorLedger,
    merger: &merge::StreamMerger,
    validators: &[proto::Validator],
    lines: &[u64],
    last_emit: f64,
) -> Result<(), String> {
    use tpufleet::util::Json;
    let Json::Obj(mut doc) = ckpt::header_json() else {
        unreachable!("checkpoint header is an object")
    };
    doc.insert("mode".to_string(), Json::str("merge"));
    doc.insert("lines".to_string(), Json::arr(lines.iter().map(|&n| Json::num(n as f64))));
    doc.insert("last_emit".to_string(), Json::f64b(last_emit));
    doc.insert("ledger".to_string(), ml.ckpt_json());
    doc.insert("merger".to_string(), merger.ckpt_json());
    doc.insert(
        "validators".to_string(),
        Json::arr(validators.iter().map(|v| v.ckpt_json())),
    );
    ckpt::write_rotating(std::path::Path::new(path), &Json::Obj(doc), keep)
        .map_err(|e| format!("writing checkpoint {path} failed: {e}"))
}

/// Read and validate a `--merge` checkpoint against this invocation's
/// stream list and window geometry.
fn read_merge_ckpt(
    path: &str,
    ids: &[String],
    width_s: f64,
    ring_windows: usize,
) -> Result<MergeResume, String> {
    let doc = ckpt::read(std::path::Path::new(path))?;
    if doc.get("mode").as_str() != Some("merge") {
        return Err("checkpoint was taken by a single-stream monitor; drop --merge".to_string());
    }
    let ml = MonitorLedger::from_ckpt(doc.get("ledger"))?;
    if ml.width_s().to_bits() != width_s.to_bits() || ml.ring_windows() != ring_windows {
        return Err(format!(
            "checkpoint was taken at --width-s {} --ring-windows {}; \
             resume with the same values (got --width-s {width_s} --ring-windows {ring_windows})",
            ml.width_s(),
            ml.ring_windows()
        ));
    }
    let merger = merge::StreamMerger::from_ckpt(doc.get("merger"))?;
    if merger.stream_count() != ids.len() {
        return Err(format!(
            "checkpoint merges {} stream(s) but --in names {}",
            merger.stream_count(),
            ids.len()
        ));
    }
    let validators = doc
        .get("validators")
        .as_arr()
        .ok_or("checkpoint: bad `validators`")?
        .iter()
        .map(proto::Validator::from_ckpt)
        .collect::<Result<Vec<_>, String>>()?;
    let lines = doc
        .get("lines")
        .as_arr()
        .ok_or("checkpoint: bad `lines`")?
        .iter()
        .map(|v| v.as_u64().ok_or_else(|| "checkpoint: bad `lines`".to_string()))
        .collect::<Result<Vec<u64>, String>>()?;
    if validators.len() != ids.len() || lines.len() != ids.len() {
        return Err("checkpoint stream counts disagree with --in".to_string());
    }
    let last_emit = doc.get("last_emit").as_f64b().ok_or("checkpoint: bad `last_emit`")?;
    Ok(MergeResume { merger, ml, validators, lines, last_emit })
}

/// `monitor --merge`: pump N stream files through the [`merge::StreamMerger`]
/// into one [`MonitorLedger`]. `--batch` buffers every stream completely
/// (unbounded reorder buffers) before draining — the watermark-ordered
/// interleaving reference — while the default path runs bounded buffers
/// with pull-based backpressure; both ingest the identical merged
/// sequence, so their snapshots are byte-identical (the CI
/// dashboard-smoke `cmp` gate).
fn cmd_monitor_merge(args: &Args, opts: MergeOpts) -> i32 {
    let MergeOpts {
        width_s,
        ring_windows,
        batch,
        follow,
        snapshot_every,
        dash,
        ckpt: ckpt_path,
        ckpt_keep,
        resume,
        quarantine,
    } = opts;
    let Some(inputs) = args.get("in") else {
        eprintln!("monitor: --merge requires --in FILE,FILE,.. (stdin cannot be merged)");
        return 2;
    };
    let paths: Vec<String> =
        inputs.split(',').map(str::trim).filter(|s| !s.is_empty()).map(str::to_string).collect();
    if paths.is_empty() {
        eprintln!("monitor: --merge requires at least one --in stream file");
        return 2;
    }
    let ids = match args.get("stream-ids") {
        Some(spec) => {
            let ids: Vec<String> = spec
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            if ids.len() != paths.len() {
                eprintln!(
                    "monitor: --stream-ids names {} stream(s) but --in has {}",
                    ids.len(),
                    paths.len()
                );
                return 2;
            }
            ids
        }
        None => {
            let mut ids = Vec::new();
            for path in &paths {
                // In follow mode the header may not be written yet; fall
                // back to the path rather than racing the writer.
                let id = if follow { None } else { stream_id_of(path).unwrap_or(None) };
                ids.push(id.unwrap_or_else(|| path.clone()));
            }
            ids
        }
    };
    let reorder_cap = args.get_usize("reorder-cap", merge::DEFAULT_REORDER_CAP);
    if reorder_cap == 0 {
        eprintln!("monitor: --reorder-cap must be at least 1");
        return 2;
    }
    // Batch mode IS the unbounded interleave: every event buffered before
    // the first pop.
    let cap = if batch { usize::MAX } else { reorder_cap };
    let sinks = MergedSinks {
        out: args.get("out").map(str::to_string),
        series_out: args.get("series-out").map(str::to_string),
        dash,
        progress: args.has_flag("progress"),
    };
    let run = || -> Result<(), String> {
        let (mut merger, mut ml, mut validators, mut lines, mut last_emit) = match &resume {
            None => (
                merge::StreamMerger::new(&ids, cap),
                MonitorLedger::new(width_s, ring_windows),
                ids.iter().map(|id| proto::Validator::labeled(id)).collect::<Vec<_>>(),
                vec![0u64; paths.len()],
                0.0_f64,
            ),
            Some(path) => {
                let r = read_merge_ckpt(path, &ids, width_s, ring_windows)?;
                eprintln!(
                    "monitor: resumed {} streams from {path}, watermark {:.1}s",
                    ids.len(),
                    r.ml.watermark_s()
                );
                (r.merger, r.ml, r.validators, r.lines, r.last_emit)
            }
        };
        let mut readers = Vec::new();
        for path in &paths {
            readers.push(TailReader::open(path, follow)?);
        }
        // Skip the raw lines the checkpointed process already consumed
        // (complete lines only — a torn tail was never counted).
        for (s, reader) in readers.iter_mut().enumerate() {
            let mut remaining = lines[s];
            while remaining > 0 {
                match reader.next_line()? {
                    Some(_) => remaining -= 1,
                    None if reader.eof => {
                        return Err(format!(
                            "[{}] is shorter than the checkpoint consumed ({} lines)",
                            ids[s], lines[s]
                        ));
                    }
                    None if follow => {
                        std::thread::sleep(std::time::Duration::from_millis(200));
                    }
                    None => {}
                }
            }
        }
        if sinks.dash.is_some() {
            emit_merged(&ml, &merger, &sinks, true, false)?;
        }
        loop {
            let mut progressed = false;
            for s in 0..paths.len() {
                while merger.wants(s) {
                    match readers[s].next_line()? {
                        Some(line) => {
                            lines[s] += 1;
                            let checked = proto::Event::parse(&line)
                                .map_err(|e| format!("[{}] line {}: {e}", ids[s], lines[s]))
                                .and_then(|ev| {
                                    if let Some(ev) = &ev {
                                        validators[s]
                                            .check(ev)
                                            .map_err(|e| format!("line {}: {e}", lines[s]))?;
                                    }
                                    Ok(ev)
                                });
                            match checked {
                                Ok(None) => continue,
                                Ok(Some(ev)) => {
                                    merger.push(s, ev);
                                    progressed = true;
                                }
                                Err(e) if quarantine => {
                                    eprintln!(
                                        "monitor: quarantining stream `{}`: {e} \
                                         (merge continues without it)",
                                        ids[s]
                                    );
                                    merger.quarantine(s, &e);
                                    progressed = true;
                                    break;
                                }
                                Err(e) => return Err(e),
                            }
                        }
                        None => {
                            if readers[s].eof {
                                merger.finish(s);
                                progressed = true;
                            }
                            break;
                        }
                    }
                }
            }
            while let Some(ev) = merger.pop() {
                ml.ingest(&ev);
                progressed = true;
                if let Some(every) = snapshot_every {
                    if ml.watermark_s() - last_emit >= every {
                        last_emit = ml.watermark_s();
                        emit_merged(&ml, &merger, &sinks, false, false)?;
                        if let Some(path) = &ckpt_path {
                            write_merge_ckpt(
                                path, ckpt_keep, &ml, &merger, &validators, &lines, last_emit,
                            )?;
                        }
                        // Chaos site: die right after snapshot +
                        // checkpoint (see the single-stream path).
                        if tpufleet::util::fault::fire(tpufleet::util::fault::Site::MonitorExit) {
                            std::process::exit(tpufleet::util::fault::INJECTED_EXIT_CODE);
                        }
                    }
                }
            }
            if merger.done() {
                break;
            }
            if !progressed {
                if follow {
                    std::thread::sleep(std::time::Duration::from_millis(200));
                } else {
                    return Err("merge stalled with no stream able to progress".to_string());
                }
            }
        }
        for (name, reason) in merger.quarantined() {
            eprintln!("monitor: stream `{name}` stayed quarantined to the end: {reason}");
        }
        emit_merged(&ml, &merger, &sinks, false, true)?;
        if let Some(path) = &ckpt_path {
            write_merge_ckpt(path, ckpt_keep, &ml, &merger, &validators, &lines, last_emit)?;
        }
        Ok(())
    };
    if let Err(e) = run() {
        eprintln!("monitor: {e}");
        return 1;
    }
    if let Some(out) = args.get("out") {
        eprintln!("wrote {out}");
    }
    0
}

fn cmd_monitor_record(args: &Args) -> i32 {
    use std::sync::{Arc, Mutex};
    let known =
        ["days", "seed", "arrivals-per-hour", "no-failures", "stream-id", "out", "inject-faults"];
    if let Some(code) = check_flags(args, "monitor record", &known) {
        return code;
    }
    if let Some(spec) = args.get("inject-faults") {
        tpufleet::util::fault::install(spec);
    }
    if args.positional.len() > 1 {
        eprintln!("usage: tpufleet monitor record [--days N] [--seed S] [--out FILE]");
        return 2;
    }
    let days = args.get_f64("days", 1.0);
    let mut cfg = SimConfig {
        seed: args.get_u64("seed", 42),
        duration_s: days * 24.0 * 3600.0,
        ..Default::default()
    };
    cfg.generator.arrivals_per_hour = args.get_f64("arrivals-per-hour", 10.0);
    if args.has_flag("no-failures") {
        cfg.failures = false;
    }
    let out = args.get("out").unwrap_or("monitor_stream.txt");
    let default_id = format!("cell-seed{}", cfg.seed);
    let stream_id = args.get("stream-id").unwrap_or(&default_id);
    eprintln!("recording {days} days (seed {}) as stream `{stream_id}`...", cfg.seed);
    let buf = Arc::new(Mutex::new(String::new()));
    let mut sim = Simulation::new(cfg).ledger_mode(tpufleet::sim::sweep::summary_ledger_mode());
    sim.attach_sink(Box::new(proto::StreamRecorder::sharing(buf.clone())));
    let res = sim.run();
    let mut stream = format!("{}\n", proto::stream_header(stream_id));
    stream.push_str(&buf.lock().expect("stream buffer poisoned"));
    stream.push_str("end\n");
    if let Err(e) = std::fs::write(out, &stream) {
        eprintln!("writing {out} failed: {e}");
        return 1;
    }
    eprintln!(
        "done: {} arrived, {} completed; wrote {} lines to {out}",
        res.arrived_jobs,
        res.completed_jobs,
        stream.lines().count()
    );
    0
}

fn cmd_overlap(args: &Args) -> i32 {
    if let Some(code) = check_flags(args, "overlap", &[]) {
        return code;
    }
    let (speedup, util) = xlaopt::overlap_case_study(ChipGeneration::TpuC);
    println!("§5.1 collective-overlap case study (500B-LLM-like profile):");
    println!("  end-to-end speedup: {speedup:.2}x   (paper: up to 1.38x)");
    println!("  FLOPs utilization:  {:.0}%   (paper: 72%)", util * 100.0);
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    /// Satellite of the dashboard PR: every new `monitor` flag is in the
    /// vocabulary, and a misspelling of any of them names the `monitor`
    /// subcommand in the rejection.
    #[test]
    fn monitor_vocabulary_accepts_every_dashboard_flag() {
        let a = parse(
            "--in a.txt,b.txt --merge --stream-ids a,b --reorder-cap 64 \
             --listen 127.0.0.1:0 --series-out s.json --snapshot-every 900 --out snap.json",
        );
        a.reject_unknown("monitor", &MONITOR_FLAGS).expect("all dashboard flags are known");
    }

    /// Satellite of the fault-tolerance PR: the checkpoint/resume and
    /// chaos flags are in the monitor vocabulary.
    #[test]
    fn monitor_vocabulary_accepts_every_fault_tolerance_flag() {
        let a = parse(
            "--in a.txt,b.txt --merge --stream-ids a,b --quarantine \
             --checkpoint mon.ckpt --resume mon.ckpt --snapshot-every 900 \
             --inject-faults monitor-exit:after=3",
        );
        a.reject_unknown("monitor", &MONITOR_FLAGS).expect("fault-tolerance flags are known");
        let err = parse("--checkpoints c").reject_unknown("monitor", &MONITOR_FLAGS).unwrap_err();
        assert!(err.contains("--checkpoints"), "{err}");
    }

    /// The auto-resume / rotation satellites: `--no-auto-resume` and
    /// `--checkpoint-keep` are in the monitor vocabulary, and their
    /// misspellings are rejected with the subcommand named.
    #[test]
    fn monitor_vocabulary_accepts_auto_resume_and_rotation_flags() {
        let a = parse(
            "--in a.txt --checkpoint mon.ckpt --checkpoint-keep 3 \
             --no-auto-resume --snapshot-every 900",
        );
        a.reject_unknown("monitor", &MONITOR_FLAGS).expect("rotation flags are known");
        for (argv, bad) in [
            ("--no-auto-resumes --checkpoint c", "--no-auto-resumes"),
            ("--checkpoint-keeps 3 --checkpoint c", "--checkpoint-keeps"),
        ] {
            let err = parse(argv).reject_unknown("monitor", &MONITOR_FLAGS).unwrap_err();
            assert!(err.starts_with("monitor: unknown flag(s)"), "{argv}: {err}");
            assert!(err.contains(bad), "{argv}: {err}");
        }
    }

    #[test]
    fn misspelled_monitor_flags_name_the_monitor_subcommand() {
        for (argv, bad) in [
            ("--mergee --in a,b", "--mergee"),
            ("--lissten 127.0.0.1:0", "--lissten"),
            ("--stream-id a,b --merge", "--stream-id"),
            ("--reorder-caps 9 --merge", "--reorder-caps"),
            ("--series-outt s.json", "--series-outt"),
        ] {
            let err = parse(argv).reject_unknown("monitor", &MONITOR_FLAGS).unwrap_err();
            assert!(err.starts_with("monitor: unknown flag(s)"), "{argv}: {err}");
            assert!(err.contains(bad), "{argv}: {err}");
        }
    }

    #[test]
    fn monitor_record_vocabulary_includes_stream_id() {
        let a = parse(
            "--days 0.1 --seed 7 --stream-id cell-a --out s.txt \
             --inject-faults stream-garble:after=40",
        );
        let known =
            ["days", "seed", "arrivals-per-hour", "no-failures", "stream-id", "out", "inject-faults"];
        a.reject_unknown("monitor record", &known).expect("record flags are known");
        let err = parse("--stream-ids a").reject_unknown("monitor record", &known).unwrap_err();
        assert!(err.starts_with("monitor record: unknown flag(s) --stream-ids"), "{err}");
    }

    /// Satellite (b): a writer appending ONE BYTE at a time — the worst
    /// legal tail — must never surface a partial line, a split multi-byte
    /// character, or a stream error. Complete lines come out exactly as
    /// written; in one-shot mode a final unterminated line is flushed at
    /// EOF, in follow mode it is held forever.
    #[test]
    fn tail_reader_survives_byte_at_a_time_writes() {
        use std::io::Write as _;
        let dir = std::env::temp_dir().join(format!("tpufleet-tail-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.txt");
        let text = "span 1 0.5 1.5 4 compile\npg 1 1.0 0.9 caf\u{e9}\ntail";
        std::fs::write(&path, b"").unwrap();
        let mut reader = TailReader::open(path.to_str().unwrap(), true).unwrap();
        let mut file = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        let mut seen = Vec::new();
        for b in text.as_bytes() {
            file.write_all(&[*b]).unwrap();
            file.flush().unwrap();
            // Drain everything available after each single byte.
            while let Some(line) = reader.next_line().unwrap() {
                seen.push(line);
            }
            assert!(!reader.eof, "follow mode never reports EOF");
        }
        assert_eq!(
            seen,
            ["span 1 0.5 1.5 4 compile\n", "pg 1 1.0 0.9 caf\u{e9}\n"],
            "only complete lines surface, multi-byte chars intact"
        );
        // One-shot mode: the same bytes, with the unterminated tail
        // flushed at EOF.
        let mut oneshot = TailReader::open(path.to_str().unwrap(), false).unwrap();
        let mut all = Vec::new();
        loop {
            match oneshot.next_line().unwrap() {
                Some(line) => all.push(line),
                None if oneshot.eof => break,
                None => {}
            }
        }
        assert_eq!(all.last().map(String::as_str), Some("tail"));
        assert_eq!(all.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
