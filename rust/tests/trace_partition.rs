//! Property suite for the partitioned job-stream contract (the descriptor
//! tentpole): parts compose under refinement, the concatenation of every
//! part of any partitioning is bit-identical to the materialized
//! [`WorkloadGenerator::trace`], and the engine produces bit-identical
//! results whether it streams a partition descriptor or replays the
//! equivalent materialized trace.

use tpufleet::fleet::ChipGeneration;
use tpufleet::sim::{JobSource, SimConfig, Simulation};
use tpufleet::workload::{
    partition_cells, CheckpointPolicy, GeneratorConfig, Job, StepProfile, TraceCheckpoints,
    TracePartition, WorkloadGenerator,
};

/// Bitwise job equality (`f64::to_bits` on every float) with exhaustive
/// destructuring: adding a `Job` field without extending this check is a
/// compile error, so the partition bit-identity contract can't silently
/// narrow.
fn assert_jobs_bit_identical(a: &Job, b: &Job, what: &str) {
    let Job {
        id,
        arrival_s,
        phase,
        framework,
        arch,
        priority,
        gen,
        slice_shape,
        pods,
        work_s,
        step,
        ckpt,
        startup_s,
    } = a;
    assert_eq!(*id, b.id, "{what}: id");
    assert_eq!(arrival_s.to_bits(), b.arrival_s.to_bits(), "{what}: arrival_s");
    assert_eq!(*phase, b.phase, "{what}: phase");
    assert_eq!(*framework, b.framework, "{what}: framework");
    assert_eq!(*arch, b.arch, "{what}: arch");
    assert_eq!(*priority, b.priority, "{what}: priority");
    assert_eq!(*gen, b.gen, "{what}: gen");
    assert_eq!(*slice_shape, b.slice_shape, "{what}: slice_shape");
    assert_eq!(*pods, b.pods, "{what}: pods");
    assert_eq!(work_s.to_bits(), b.work_s.to_bits(), "{what}: work_s");
    assert_eq!(startup_s.to_bits(), b.startup_s.to_bits(), "{what}: startup_s");
    let StepProfile { ideal_flops_per_chip, base_efficiency, comm_fraction, host_fraction } =
        step;
    assert_eq!(
        ideal_flops_per_chip.to_bits(),
        b.step.ideal_flops_per_chip.to_bits(),
        "{what}: step.ideal_flops_per_chip"
    );
    assert_eq!(
        base_efficiency.to_bits(),
        b.step.base_efficiency.to_bits(),
        "{what}: step.base_efficiency"
    );
    assert_eq!(
        comm_fraction.to_bits(),
        b.step.comm_fraction.to_bits(),
        "{what}: step.comm_fraction"
    );
    assert_eq!(
        host_fraction.to_bits(),
        b.step.host_fraction.to_bits(),
        "{what}: step.host_fraction"
    );
    let CheckpointPolicy { interval_s, write_stall_s, restore_s } = ckpt;
    assert_eq!(interval_s.to_bits(), b.ckpt.interval_s.to_bits(), "{what}: ckpt.interval_s");
    assert_eq!(
        write_stall_s.to_bits(),
        b.ckpt.write_stall_s.to_bits(),
        "{what}: ckpt.write_stall_s"
    );
    assert_eq!(restore_s.to_bits(), b.ckpt.restore_s.to_bits(), "{what}: ckpt.restore_s");
}

fn assert_traces_bit_identical(a: &[Job], b: &[Job], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: job count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_jobs_bit_identical(x, y, &format!("{what}: job {i}"));
    }
}

fn part(cfg: &GeneratorConfig, j: u64, n: u64) -> Vec<Job> {
    TracePartition::new(cfg.clone(), j, n).collect()
}

/// Concatenating every part of an n-way partitioning reproduces the full
/// materialized trace bitwise — for n below, at, and above the cell count.
#[test]
fn concat_of_all_parts_is_the_materialized_trace() {
    let cfg = GeneratorConfig { duration_s: 2.0 * 86400.0, ..Default::default() };
    let cells = partition_cells(cfg.duration_s);
    assert_eq!(cells, 48);
    let full = WorkloadGenerator::new(cfg.clone()).trace();
    assert!(full.len() > 500, "trace too small to exercise boundaries: {}", full.len());
    for n in [1u64, 2, 5, 48, 97] {
        let concat: Vec<Job> = (0..n).flat_map(|j| part(&cfg, j, n)).collect();
        assert_traces_bit_identical(&full, &concat, &format!("{n} parts"));
    }
}

/// The composability law: refining an n-way partitioning k-fold subdivides
/// parts without moving any boundary, so parts `j·k .. (j+1)·k` of `n·k`
/// concatenate to exactly part `j` of `n`.
#[test]
fn refinement_composability_parts_subdivide_exactly() {
    let cfg = GeneratorConfig { duration_s: 30.0 * 3600.0, ..Default::default() };
    for (n, k) in [(2u64, 5u64), (3, 4), (5, 2), (1, 10)] {
        for j in 0..n {
            let coarse = part(&cfg, j, n);
            let refined: Vec<Job> =
                (j * k..(j + 1) * k).flat_map(|jf| part(&cfg, jf, n * k)).collect();
            assert_traces_bit_identical(
                &coarse,
                &refined,
                &format!("part {j} of {n} vs parts {}..{} of {}", j * k, (j + 1) * k, n * k),
            );
        }
    }
}

/// Randomized composability: arbitrary seeds, rates, non-round durations,
/// and part counts — concat equals trace, and the O(1) checkpoint jump
/// equals the replay fast-forward, part by part.
#[test]
fn random_configs_uphold_partition_laws() {
    tpufleet::testkit::check(6, 0x7A27, |rng| {
        let cfg = GeneratorConfig {
            seed: rng.below(u64::MAX),
            arrivals_per_hour: rng.range_f64(4.0, 24.0),
            duration_s: rng.range_f64(0.5, 40.0) * 3600.0,
            ..Default::default()
        };
        let n = 1 + rng.below(9);
        let full = WorkloadGenerator::new(cfg.clone()).trace();
        let ckpts = TraceCheckpoints::build(&cfg);
        assert_eq!(ckpts.cells(), partition_cells(cfg.duration_s));
        let mut concat = Vec::new();
        for j in 0..n {
            let replayed = part(&cfg, j, n);
            let jumped: Vec<Job> =
                TracePartition::with_checkpoints(cfg.clone(), j, n, &ckpts).collect();
            assert_traces_bit_identical(
                &replayed,
                &jumped,
                &format!("checkpoint jump, part {j} of {n}"),
            );
            concat.extend(replayed);
        }
        assert_traces_bit_identical(&full, &concat, &format!("concat of {n} parts"));
    });
}

fn engine_cfg() -> SimConfig {
    let mut cfg = SimConfig {
        seed: 0xD15C,
        duration_s: 2.0 * 86400.0,
        static_fleet: vec![(ChipGeneration::TpuC, 20)],
        ..Default::default()
    };
    cfg.generator.gen_mix = vec![(ChipGeneration::TpuC, 1.0)];
    cfg.generator.arrivals_per_hour = 10.0;
    cfg
}

/// Materialize the slice of the generator stream a descriptor denotes,
/// under the engine's horizon override (the engine bounds the stream by
/// `SimConfig::duration_s`, not the generator's nominal duration).
fn materialize(cfg: &SimConfig, part_index: u64, part_count: u64) -> Vec<Job> {
    let mut gcfg = cfg.generator.clone();
    gcfg.duration_s = cfg.duration_s;
    TracePartition::new(gcfg, part_index, part_count).collect()
}

/// The engine contract: a descriptor-backed run and the run replaying the
/// equivalent materialized trace produce an equal `SimResult` and a
/// bit-identical `GoodputReport`. This is what lets sweep/shard configs
/// carry two integers instead of O(jobs) serialized records.
#[test]
fn engine_results_bit_identical_descriptor_vs_materialized() {
    for (part_index, part_count) in [(0u64, 1u64), (1, 2)] {
        let mut desc_cfg = engine_cfg();
        desc_cfg.source = JobSource::Partition { part_index, part_count };
        let mut mat_cfg = engine_cfg();
        mat_cfg.source = JobSource::materialized(materialize(&mat_cfg, part_index, part_count));

        let mut desc = Simulation::new(desc_cfg);
        let r_desc = desc.run();
        let mut mat = Simulation::new(mat_cfg);
        let r_mat = mat.run();
        assert!(
            r_desc.arrived_jobs > 0,
            "part {part_index}/{part_count} must see arrivals: {r_desc:?}"
        );
        assert_eq!(
            r_desc, r_mat,
            "SimResult must not depend on the source representation \
             (part {part_index}/{part_count})"
        );
        tpufleet::testkit::assert_reports_bit_identical(
            &desc.fleet_goodput(),
            &mat.fleet_goodput(),
            &format!("descriptor vs materialized, part {part_index}/{part_count}"),
        );
    }
}
