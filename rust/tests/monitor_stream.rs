//! End-to-end contract for the streaming fleet monitor: a recorded
//! simulation stream driven through [`MonitorLedger`] must report
//! `f64::to_bits`-identical to the batch [`WindowedLedger`] replaying
//! the same stream with the horizon known up front, while holding only
//! O(ring_windows × live jobs) cells no matter how long the stream runs.

use std::sync::{Arc, Mutex};

use tpufleet::metrics::{StackLayer, TimeClass, WindowedLedger};
use tpufleet::monitor::proto::{Event, StreamRecorder, Validator};
use tpufleet::monitor::{snapshot_json, MonitorLedger, StreamStats};
use tpufleet::sim::{SimConfig, Simulation};
use tpufleet::testkit::assert_reports_bit_identical;

/// Record a simulation's span emission as protocol lines.
fn recorded_stream(seed: u64, days: f64) -> String {
    let mut cfg = SimConfig { seed, duration_s: days * 86400.0, ..Default::default() };
    cfg.generator.arrivals_per_hour = 8.0;
    let buf = Arc::new(Mutex::new(String::new()));
    let mut sim = Simulation::new(cfg).ledger_mode(tpufleet::sim::sweep::summary_ledger_mode());
    sim.attach_sink(Box::new(StreamRecorder::sharing(buf.clone())));
    sim.run();
    let mut stream = buf.lock().unwrap().clone();
    stream.push_str("end\n");
    stream
}

/// Parse + validate every line the way the `monitor` subcommand does.
fn parse_stream(text: &str) -> Vec<Event> {
    let mut validator = Validator::default();
    let mut evs = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(ev) = Event::parse(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1)) {
            validator.check(&ev).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
            evs.push(ev);
        }
    }
    evs
}

fn replay_batch(evs: &[Event], horizon_s: f64, width_s: f64) -> WindowedLedger {
    let mut win = WindowedLedger::new(horizon_s, width_s);
    for ev in evs {
        match *ev {
            Event::Capacity { t, chips } => win.set_capacity(t, chips),
            Event::Job(ref m) => win.ensure_job(m.clone()),
            Event::Span { id, t0, t1, chips, class, layer } => {
                win.add_span(id, t0, t1, chips, class, layer)
            }
            Event::Pg { id, t0, t1, chips, pg } => win.add_pg_sample(id, t0, t1, chips, pg),
            Event::End => {}
        }
    }
    win
}

/// The watermark the streaming mode converges to: the same `f64::max`
/// fold over event end-times that `MonitorLedger::advance` runs.
fn watermark(evs: &[Event]) -> f64 {
    evs.iter().filter_map(Event::end_time).fold(0.0, f64::max)
}

#[test]
fn recorded_sim_stream_matches_batch_replay_bitwise() {
    let stream = recorded_stream(0x9011, 1.0);
    let evs = parse_stream(&stream);
    assert!(evs.iter().any(|e| matches!(e, Event::Span { .. })), "stream has spans");
    let mut ml = MonitorLedger::new(3600.0, 6);
    for ev in &evs {
        ml.ingest(ev);
    }
    assert!(ml.evicted_cells() > 0, "a 24h stream must overflow a 6h ring");
    let win = replay_batch(&evs, watermark(&evs), 3600.0);
    assert_eq!(ml.watermark_s().to_bits(), watermark(&evs).to_bits());
    assert_reports_bit_identical(&ml.report(|_| true), &win.report(|_| true), "fleet");
    // Filtered views go through the same merge path.
    assert_reports_bit_identical(
        &ml.report(|m| m.chips >= 256),
        &win.report(|m| m.chips >= 256),
        "large jobs",
    );
    // The snapshot document — what `monitor` vs `monitor --batch` emit
    // and CI `cmp`s — is byte-identical too.
    let stats = StreamStats {
        jobs: ml.job_count(),
        spans: ml.span_count(),
        pg_samples: ml.pg_count(),
        cap_events: ml.cap_events(),
    };
    let a = snapshot_json(&ml.report(|_| true), ml.watermark_s(), 3600.0, &stats, true);
    let b = snapshot_json(&win.report(|_| true), watermark(&evs), 3600.0, &stats, true);
    assert_eq!(a.to_string_pretty(), b.to_string_pretty());
}

#[test]
fn ring_memory_stays_bounded_on_streams_far_longer_than_the_ring() {
    // 4-window ring of 100 s windows; the stream runs 40× the ring
    // horizon with two interleaved jobs and periodic capacity wobble.
    let mut evs = vec![Event::Capacity { t: 0.0, chips: 512 }];
    let meta = |id: u64| {
        match Event::parse(&format!(
            "job {id} training jax-pathways transformer tpu-c small 64"
        )) {
            Ok(Some(ev)) => ev,
            other => panic!("meta line: {other:?}"),
        }
    };
    evs.push(meta(1));
    evs.push(meta(2));
    for k in 0..4000u64 {
        let t = k as f64 * 4.0;
        evs.push(Event::Span {
            id: 1 + (k % 2),
            t0: t,
            t1: t + 6.0,
            chips: 8,
            class: TimeClass::ALL[(k % 7) as usize],
            layer: StackLayer::ALL[(k % 6) as usize],
        });
        if k % 7 == 0 {
            evs.push(Event::Pg { id: 1, t0: t, t1: t + 6.0, chips: 8, pg: 0.75 });
        }
        if k % 500 == 250 {
            evs.push(Event::Capacity { t, chips: 512 - k / 10 });
        }
    }
    let mut ml = MonitorLedger::new(100.0, 4);
    for ev in &evs {
        ml.ingest(ev);
    }
    // The bounded-memory guarantee: peak cells never exceed the ring
    // bound, even though 161 windows (and their cells) streamed through.
    assert_eq!(ml.windows_started(), 161);
    assert!(ml.peak_cells() <= ml.ring_windows() * ml.peak_live_jobs());
    assert!(ml.peak_cells() <= 4 * 2);
    assert!(ml.evicted_cells() as usize >= ml.windows_started() - ml.ring_windows());
    // ...and the whole-stream report is still exact.
    let win = replay_batch(&evs, watermark(&evs), 100.0);
    assert_reports_bit_identical(&ml.report(|_| true), &win.report(|_| true), "fleet");
    assert_reports_bit_identical(&ml.report(|m| m.id == 2), &win.report(|m| m.id == 2), "job 2");
}

#[test]
fn protocol_lines_round_trip_every_recorded_event() {
    let stream = recorded_stream(0xCAFE, 0.5);
    let mut n = 0;
    for line in stream.lines() {
        let Some(ev) = Event::parse(line).expect("recorded line parses") else {
            continue;
        };
        assert_eq!(ev.format(), line, "format(parse(line)) reproduces the line");
        n += 1;
    }
    assert!(n > 100, "expected a substantive stream, got {n} events");
}

#[test]
fn follow_mode_tail_ingest_matches_one_shot_replay() {
    // Satellite contract for `monitor --follow`: a follower tailing a
    // file that is being appended to concurrently must land on exactly
    // the snapshot a one-shot replay of the finished stream produces.
    use std::io::Write as _;
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_tpufleet");
    let dir = std::env::temp_dir().join(format!("tpufleet-follow-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("creating scratch dir");
    let stream = recorded_stream(0xF011, 0.25);
    let lines: Vec<&str> = stream.lines().collect();
    let full_path = dir.join("full.txt");
    let tail_path = dir.join("tail.txt");
    std::fs::write(&full_path, &stream).unwrap();
    // Seed the tailed file with the first 40%, then start the follower.
    let head = lines.len() * 2 / 5;
    let seed_text: String = lines[..head].iter().map(|l| format!("{l}\n")).collect();
    std::fs::write(&tail_path, &seed_text).unwrap();
    let follow_snap = dir.join("follow.json");
    let mut child = Command::new(bin)
        .args(["monitor", "--in", &tail_path.display().to_string(), "--follow"])
        .args(["--width-s", "1800", "--ring-windows", "6"])
        .args(["--out", &follow_snap.display().to_string()])
        .spawn()
        .expect("spawning follower");
    // Append the rest in a few bursts while the follower is reading;
    // the last burst carries the `end` line that lets it finish.
    let mut file = std::fs::OpenOptions::new().append(true).open(&tail_path).unwrap();
    for chunk in lines[head..].chunks(lines.len() / 4 + 1) {
        std::thread::sleep(std::time::Duration::from_millis(250));
        let text: String = chunk.iter().map(|l| format!("{l}\n")).collect();
        file.write_all(text.as_bytes()).unwrap();
        file.flush().unwrap();
    }
    drop(file);
    let status = child.wait().expect("waiting for follower");
    assert!(status.success(), "follower exited with {status}");
    let once_snap = dir.join("once.json");
    let ok = Command::new(bin)
        .args(["monitor", "--in", &full_path.display().to_string()])
        .args(["--width-s", "1800", "--ring-windows", "6"])
        .args(["--out", &once_snap.display().to_string()])
        .status()
        .expect("spawning one-shot monitor")
        .success();
    assert!(ok, "one-shot monitor failed");
    let follow = std::fs::read_to_string(&follow_snap).unwrap();
    let once = std::fs::read_to_string(&once_snap).unwrap();
    assert_eq!(follow, once, "tail ingest must converge on the one-shot snapshot bytes");
}

#[test]
fn recorder_and_primary_ledger_see_the_same_emission() {
    // The recorder is a passive observer: attaching it must not perturb
    // the primary ledger's accounting (same config, same seed, with and
    // without the sink).
    let mut cfg = SimConfig { seed: 0x0B5, duration_s: 0.5 * 86400.0, ..Default::default() };
    cfg.generator.arrivals_per_hour = 6.0;
    let mut plain = Simulation::new(cfg.clone());
    plain.run();
    let buf = Arc::new(Mutex::new(String::new()));
    let mut observed = Simulation::new(cfg);
    observed.attach_sink(Box::new(StreamRecorder::sharing(buf.clone())));
    observed.run();
    assert_reports_bit_identical(
        &plain.fleet_goodput(),
        &observed.fleet_goodput(),
        "observer must not perturb the run",
    );
    // And the recorded stream carries the jobs the ledger accounted.
    let mut stream = buf.lock().unwrap().clone();
    stream.push_str("end\n");
    let evs = parse_stream(&stream);
    let mut sink_jobs = std::collections::BTreeSet::new();
    for ev in &evs {
        if let Event::Job(m) = ev {
            sink_jobs.insert(m.id);
        }
    }
    assert_eq!(sink_jobs.len(), observed.ledger.jobs.len());
}
