//! End-to-end determinism suite for `sweep --shards`: the merged report
//! of a multi-process sharded run must be byte-identical to the
//! single-process report for the same grid — cold cache, warm cache, and
//! after a shard is killed mid-run and the sweep re-run (resume from the
//! shared cache).
//!
//! These tests drive the real `tpufleet` binary (Cargo builds it for
//! integration tests and exposes the path via `CARGO_BIN_EXE_tpufleet`),
//! so the coordinator/worker subprocess plumbing, the manifest hand-off,
//! and the merge all run exactly as they do for an operator.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_tpufleet")
}

/// Fresh scratch dir under the OS temp dir (unique per process + tag so
/// parallel `cargo test` threads never collide).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tpufleet-shard-determinism-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("creating scratch dir");
    dir
}

/// A tiny 6-variant grid (3 policies x 2 fleets x 1 x 1) over ~1.2
/// simulated hours: large enough to exercise every merge path, small
/// enough that the whole suite stays in CI-smoke territory.
fn sweep_args(out: &Path, cache: &Path) -> Vec<String> {
    let fixed = ["sweep", "--days", "0.05", "--seed", "77", "--workers", "1"];
    let mut args: Vec<String> = fixed.iter().map(|s| s.to_string()).collect();
    args.push("--arrivals-per-hour".to_string());
    args.push("8".to_string());
    args.push("--out".to_string());
    args.push(out.display().to_string());
    args.push("--cache-dir".to_string());
    args.push(cache.display().to_string());
    args
}

fn run(args: &[String], envs: &[(&str, &str)]) -> Output {
    let mut cmd = Command::new(bin());
    cmd.args(args);
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.output().expect("spawning tpufleet")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

#[test]
fn sharded_reports_byte_identical_to_serial_cold_and_warm() {
    let dir = scratch("byteident");
    let serial_out = dir.join("serial.json");
    let serial_cache = dir.join("cache-serial");
    let st = run(&sweep_args(&serial_out, &serial_cache), &[]);
    assert!(st.status.success(), "serial sweep failed: {}", stderr_of(&st));
    let reference = read(&serial_out);
    assert!(reference.contains("\"variants\""), "report must have rows");

    for shards in [1usize, 2, 5] {
        let out = dir.join(format!("sharded-{shards}.json"));
        let cache = dir.join(format!("cache-{shards}"));
        let mut args = sweep_args(&out, &cache);
        args.push("--shards".to_string());
        args.push(shards.to_string());

        // Cold: every variant simulated inside worker subprocesses.
        let cold = run(&args, &[]);
        assert!(
            cold.status.success(),
            "{shards}-shard cold sweep failed: {}",
            stderr_of(&cold)
        );
        assert_eq!(
            reference,
            read(&out),
            "{shards}-shard cold merged report must be byte-identical to serial"
        );
        let shard_dir = dir.join(format!("sharded-{shards}.json.shards"));
        assert!(
            !shard_dir.exists(),
            "scratch shard dir must be cleaned up after success"
        );

        // Warm: same command again, now all cache hits — and the exact
        // same bytes (wall-clock and hit/miss telemetry live on stderr).
        let warm = run(&args, &[]);
        assert!(
            warm.status.success(),
            "{shards}-shard warm sweep failed: {}",
            stderr_of(&warm)
        );
        assert_eq!(
            reference,
            read(&out),
            "{shards}-shard warm merged report must be byte-identical to serial"
        );
        assert!(
            stderr_of(&warm).contains("(6/6 cache hits"),
            "warm re-run must be served entirely from the cache: {}",
            stderr_of(&warm)
        );
    }
}

#[test]
fn shards_share_one_cache_with_serial_runs() {
    let dir = scratch("sharedcache");
    let cache = dir.join("cache");
    // Warm the cache with a plain serial run...
    let serial_out = dir.join("serial.json");
    let st = run(&sweep_args(&serial_out, &cache), &[]);
    assert!(st.status.success(), "serial sweep failed: {}", stderr_of(&st));
    // ...then the sharded run over the same grid must be all hits.
    let out = dir.join("sharded.json");
    let mut args = sweep_args(&out, &cache);
    args.push("--shards".to_string());
    args.push("2".to_string());
    let sharded = run(&args, &[]);
    assert!(sharded.status.success(), "sharded sweep failed: {}", stderr_of(&sharded));
    assert!(
        stderr_of(&sharded).contains("(6/6 cache hits"),
        "workers must hit the cache the serial run warmed: {}",
        stderr_of(&sharded)
    );
    assert_eq!(read(&serial_out), read(&out));
}

#[test]
fn killed_shard_run_resumes_from_cache() {
    let dir = scratch("resume");
    // Byte-identity reference.
    let serial_out = dir.join("serial.json");
    let st = run(&sweep_args(&serial_out, &dir.join("cache-serial")), &[]);
    assert!(st.status.success(), "serial sweep failed: {}", stderr_of(&st));

    let cache = dir.join("cache");
    let out = dir.join("sharded.json");
    let mut args = sweep_args(&out, &cache);
    args.push("--shards".to_string());
    args.push("2".to_string());

    // Every worker dies after its first variant (the TPUFLEET_SHARD_FAIL_AFTER
    // test hook): the coordinator must fail loudly...
    let killed = run(&args, &[("TPUFLEET_SHARD_FAIL_AFTER", "1")]);
    assert!(!killed.status.success(), "coordinator must fail when a shard dies");
    assert!(
        stderr_of(&killed).contains("re-run"),
        "failure message must point at resume semantics: {}",
        stderr_of(&killed)
    );

    // ...but each worker finished (and cached) exactly one variant first,
    // so the re-run resumes: 2 hits, 4 fresh simulations, and a merged
    // report byte-identical to the serial reference.
    let resumed = run(&args, &[]);
    assert!(resumed.status.success(), "resume run failed: {}", stderr_of(&resumed));
    assert!(
        stderr_of(&resumed).contains("(2/6 cache hits"),
        "resume must reuse the killed run's cached variants: {}",
        stderr_of(&resumed)
    );
    assert_eq!(
        read(&serial_out),
        read(&out),
        "resumed merged report must be byte-identical to serial"
    );
}

#[test]
fn materialized_trace_run_matches_descriptor_runs_bytewise() {
    // The JobSource contract on the real binary: --materialize-trace
    // converts every variant's partition descriptor into an explicit
    // Vec<Job> replay before sweeping, and the report must come out
    // byte-identical to the descriptor-backed serial AND 2-shard runs
    // (whose manifests carry only descriptor integers).
    let dir = scratch("materialize");
    let mat_out = dir.join("materialized.json");
    let mut mat_args = sweep_args(&mat_out, &dir.join("cache-mat"));
    mat_args.push("--materialize-trace".to_string());
    let mat = run(&mat_args, &[]);
    assert!(mat.status.success(), "materialized sweep failed: {}", stderr_of(&mat));
    let reference = read(&mat_out);

    let desc_out = dir.join("descriptor.json");
    let desc = run(&sweep_args(&desc_out, &dir.join("cache-desc")), &[]);
    assert!(desc.status.success(), "descriptor sweep failed: {}", stderr_of(&desc));
    assert_eq!(
        reference,
        read(&desc_out),
        "materialized and descriptor-backed reports must be byte-identical"
    );

    let sh_out = dir.join("sharded.json");
    let mut sh_args = sweep_args(&sh_out, &dir.join("cache-sh"));
    sh_args.push("--shards".to_string());
    sh_args.push("2".to_string());
    let sh = run(&sh_args, &[]);
    assert!(sh.status.success(), "sharded sweep failed: {}", stderr_of(&sh));
    assert_eq!(
        reference,
        read(&sh_out),
        "descriptor-manifest sharded report must match the materialized run"
    );
}

#[test]
fn pre_descriptor_cache_entries_read_as_misses_end_to_end() {
    // CACHE_VERSION 3 -> 4 migration on the real binary: v3 entries were
    // keyed under the old trace_jobs hash shape, so a v3 version stamp
    // must read as a miss — the sweep re-simulates everything (0/6 hits)
    // and still produces byte-identical output, rather than trusting a
    // stale entry or failing.
    let dir = scratch("stalecache");
    let cache = dir.join("cache");
    let out = dir.join("report.json");
    let cold = run(&sweep_args(&out, &cache), &[]);
    assert!(cold.status.success(), "cold sweep failed: {}", stderr_of(&cold));
    let reference = read(&out);

    let warm = run(&sweep_args(&out, &cache), &[]);
    assert!(warm.status.success(), "warm sweep failed: {}", stderr_of(&warm));
    assert!(
        stderr_of(&warm).contains("(6/6 cache hits"),
        "sanity: warm run must be all hits: {}",
        stderr_of(&warm)
    );

    // Downgrade every entry's version stamp to 3 in place.
    let mut rewritten = 0;
    for e in std::fs::read_dir(&cache).expect("reading cache dir") {
        let path = e.expect("cache dir entry").path();
        if path.extension().is_some_and(|x| x == "json") {
            let text = read(&path);
            let stale = text.replace("\"version\": 4", "\"version\": 3");
            assert_ne!(stale, text, "entry must carry a v4 stamp: {}", path.display());
            std::fs::write(&path, stale).expect("rewriting cache entry");
            rewritten += 1;
        }
    }
    assert!(rewritten >= 6, "expected >= 6 cache entries, rewrote {rewritten}");

    let stale_run = run(&sweep_args(&out, &cache), &[]);
    assert!(stale_run.status.success(), "stale-cache sweep failed: {}", stderr_of(&stale_run));
    assert!(
        stderr_of(&stale_run).contains("(0/6 cache hits"),
        "v3 entries must all read as misses: {}",
        stderr_of(&stale_run)
    );
    assert_eq!(reference, read(&out), "re-simulated report must be byte-identical");
}

#[test]
fn cache_stats_flag_reports_footprint() {
    let dir = scratch("cachestats");
    let out = dir.join("report.json");
    let mut args = sweep_args(&out, &dir.join("cache"));
    args.push("--cache-stats".to_string());
    let st = run(&args, &[]);
    assert!(st.status.success(), "sweep failed: {}", stderr_of(&st));
    let err = stderr_of(&st);
    assert!(
        err.contains("cache stats:") && err.contains("6 entries"),
        "--cache-stats must report the cache footprint: {err}"
    );
}
