//! Integration: the paper's figure "shapes" hold on the full simulator
//! (the per-figure expected shapes are indexed in DESIGN.md §6).

use tpufleet::report::figures;
use tpufleet::workload::SizeClass;

#[test]
fn fig14_shape_pathways_training_leads_rg_speedup() {
    let fig = figures::fig14_rg_segments(0x14_14);
    let series: std::collections::HashMap<&str, &Vec<f64>> = fig
        .series
        .iter()
        .map(|(label, v)| (label.as_str(), v))
        .collect();
    let last = |label: &str| -> f64 {
        let v = series[label];
        // Last full week with data.
        *v.iter().rev().find(|&&x| x > 0.0).unwrap_or(&0.0)
    };
    let first = |label: &str| -> f64 {
        *series[label].iter().find(|&&x| x > 0.0).unwrap_or(&0.0)
    };
    // Every segment ends at or above its start (the quarter deployed
    // improvements, not regressions)...
    for (label, _) in &fig.series {
        assert!(
            last(label) >= first(label) * 0.95,
            "{label}: {} -> {}",
            first(label),
            last(label)
        );
    }
    // ...and the Pathways training segment holds the highest RG level week
    // after week (the paper's Fig. 14 observation: "training workloads
    // running JAX with Pathways tend to have a higher RG"). Its *speedup*
    // is smaller exactly because it starts with less stall to remove.
    let a = series["A: training+pathways"];
    let b = series["B: training+multi-client"];
    let weeks_a_leads = a
        .iter()
        .zip(b.iter())
        .filter(|&(&x, &y)| x > 0.0 && y > 0.0 && x >= y)
        .count();
    let weeks_with_data = a
        .iter()
        .zip(b.iter())
        .filter(|&(&x, &y)| x > 0.0 && y > 0.0)
        .count();
    assert!(
        weeks_a_leads * 10 >= weeks_with_data * 8,
        "pathways training should lead RG most weeks: {weeks_a_leads}/{weeks_with_data}"
    );
}

#[test]
fn fig15_shape_bulk_inference_dips_in_months_3_to_6() {
    let fig = figures::fig15_rg_phase(0x15_15);
    let bulk: Vec<f64> = fig.rg.iter().map(|r| r[2]).collect();
    let train: Vec<f64> = fig.rg.iter().map(|r| r[0]).collect();
    // Months 0..3 healthy vs months 3..6 dipped.
    let early = (bulk[0] + bulk[1] + bulk[2]) / 3.0;
    let late = (bulk[3] + bulk[4] + bulk[5]) / 3.0;
    assert!(late < early * 0.93, "bulk RG must dip: {early:.3} -> {late:.3}");
    // Training stays comparatively stable and above bulk in the dip.
    let train_late = (train[3] + train[4] + train[5]) / 3.0;
    assert!(train_late > late, "training {train_late:.3} vs bulk {late:.3}");
    let train_early = (train[0] + train[1] + train[2]) / 3.0;
    assert!(
        (train_late - train_early).abs() < 0.15 * train_early.max(1e-9),
        "training should be stable: {train_early:.3} -> {train_late:.3}"
    );
}

#[test]
fn fig16_shape_sg_u_curve_and_95_percent_floor() {
    let fig = figures::fig16_sg_jobsize(0x16_16);
    let sg = |size: SizeClass| -> f64 {
        fig.sg_by_size.iter().find(|&&(s, _)| s == size).map(|&(_, v)| v).unwrap()
    };
    let small = sg(SizeClass::Small);
    let medium = sg(SizeClass::Medium);
    let large = sg(SizeClass::Large);
    let xl = sg(SizeClass::ExtraLarge);
    eprintln!("SG by size: small={small:.4} medium={medium:.4} large={large:.4} xl={xl:.4}");
    // Paper: SG > 95% for all size classes.
    for (label, v) in [("small", small), ("medium", medium), ("large", large), ("xl", xl)] {
        assert!(v > 0.95, "{label} SG {v} below the paper's 95% floor");
    }
    // U-shape: small and XL at least match the middle classes.
    let mid = medium.min(large);
    assert!(small >= mid, "small {small} < mid {mid}");
    assert!(xl >= mid * 0.995, "xl {xl} substantially below mid {mid}");
}

#[test]
fn overlap_case_study_reproduces_paper_band() {
    let (speedup, util) =
        tpufleet::xlaopt::overlap_case_study(tpufleet::fleet::ChipGeneration::TpuC);
    assert!(speedup > 1.2 && speedup < 1.6, "speedup={speedup}");
    assert!((util - 0.72).abs() < 0.1, "util={util} (paper: 0.72)");
}

#[test]
fn year_scale_workload_population_drifts_like_fig4_and_fig6() {
    let f4 = figures::fig4_job_sizes(0x44);
    assert!(f4.quarters[3][3] > f4.quarters[0][3] * 1.3, "XL demand share grows");
    let f6 = figures::fig6_pathways(0x66);
    // Adoption is an S-curve: strictly higher at end, monotone-ish.
    let (first, last) = (f6.monthly_share[0], f6.monthly_share[11]);
    assert!(last > first + 0.25);
    let increasing_pairs = f6
        .monthly_share
        .windows(2)
        .filter(|w| w[1] >= w[0] - 0.05)
        .count();
    assert!(increasing_pairs >= 9, "adoption should be near-monotone");
}

#[test]
fn trace_replay_is_deterministic_and_matches_generator_run() {
    use tpufleet::sim::{SimConfig, Simulation};
    use tpufleet::workload::{trace, WorkloadGenerator};
    let mut cfg = SimConfig { seed: 0x7A, duration_s: 2.0 * 86400.0, ..Default::default() };
    cfg.generator.arrivals_per_hour = 8.0;
    // Generator-driven run.
    let mut direct = Simulation::new(cfg.clone());
    let r_direct = direct.run();
    // Same jobs exported + replayed through the trace path.
    let mut gcfg = cfg.generator.clone();
    gcfg.duration_s = cfg.duration_s;
    let jobs = WorkloadGenerator::new(gcfg).trace();
    let json = trace::to_json(&jobs);
    let restored = trace::from_json(&json).unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.source = tpufleet::sim::JobSource::materialized(restored);
    let mut replay = Simulation::new(cfg2.clone());
    let r_replay = replay.run();
    assert_eq!(r_direct.arrived_jobs, r_replay.arrived_jobs);
    assert_eq!(r_direct.completed_jobs, r_replay.completed_jobs);
    assert_eq!(r_direct.preemptions, r_replay.preemptions);
    // And replaying twice is identical.
    let mut replay2 = Simulation::new(cfg2);
    let r_replay2 = replay2.run();
    assert_eq!(r_replay.completed_jobs, r_replay2.completed_jobs);
}

#[test]
fn ablations_have_paper_consistent_directions() {
    let ab = figures::ablations(0xAB1A);
    let row = |name: &str| ab.rows.iter().find(|r| r.name == name).unwrap();
    // Async checkpointing strictly beats sync on RG (same trace).
    assert!(
        row("async-ckpt-all").rg > row("sync-ckpt-only").rg,
        "async {} vs sync {}",
        row("async-ckpt-all").rg,
        row("sync-ckpt-only").rg
    );
    // Disabling preemption collapses preemption counts (failures remain).
    assert!(row("no-preemption").preemptions < row("baseline").preemptions / 5);
    // Headroom trades throughput (completions) for stability.
    assert!(row("headroom-15%").completed < row("baseline").completed);
    // Every variant still yields bounded goodputs.
    for r in &ab.rows {
        for v in [r.sg, r.rg, r.pg, r.mpg] {
            assert!((0.0..=1.0).contains(&v), "{}: {v}", r.name);
        }
    }
}
