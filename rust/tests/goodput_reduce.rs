//! Property suite for the single-pass MPG reduction engine and the
//! streaming windowed ledger: for random ledgers and real simulations,
//! every optimized path must be bit-identical (`f64::to_bits`) to the
//! retained naive reference — the contract that keeps warm sweep caches
//! and shard merges byte-identical with no `SIM_BEHAVIOR_VERSION` bump.

use tpufleet::fleet::ChipGeneration;
use tpufleet::metrics::goodput::{self, Axis};
use tpufleet::metrics::{JobMeta, Ledger, StackLayer, TimeClass, TimeSeries, WindowedLedger};
use tpufleet::sim::{shard, LedgerMode, SimConfig, SweepRunner, SweepSpec, SweepSummary};
use tpufleet::testkit::check;
use tpufleet::util::Rng;
use tpufleet::workload::{
    CheckpointPolicy, Framework, Job, ModelArch, Phase, Priority, StepProfile,
};

fn random_job(rng: &mut Rng, id: u64) -> Job {
    let gens = [ChipGeneration::TpuB, ChipGeneration::TpuC, ChipGeneration::TpuD];
    let gen = gens[rng.below(3) as usize];
    let pod = gen.spec().pod_shape;
    let (slice_shape, pods) = if rng.chance(0.2) {
        ([0, 0, 0], rng.range_u64(1, 3) as u32)
    } else {
        let s = [
            rng.range_u64(1, pod[0] as u64) as u32,
            rng.range_u64(1, pod[1] as u64) as u32,
            rng.range_u64(1, pod[2] as u64) as u32,
        ];
        (s, 0)
    };
    let phases = [Phase::Training, Phase::Serving, Phase::BulkInference];
    Job {
        id,
        arrival_s: rng.range_f64(0.0, 500.0),
        phase: phases[rng.below(3) as usize],
        framework: Framework::ALL[rng.below(3) as usize],
        arch: ModelArch::ALL[rng.below(4) as usize],
        priority: Priority::Prod,
        gen,
        slice_shape,
        pods,
        work_s: rng.range_f64(100.0, 20_000.0),
        step: StepProfile {
            ideal_flops_per_chip: rng.range_f64(1e10, 1e13),
            base_efficiency: rng.range_f64(0.1, 0.9),
            comm_fraction: rng.range_f64(0.0, 0.7),
            host_fraction: rng.range_f64(0.0, 0.6),
        },
        ckpt: CheckpointPolicy::synchronous(),
        startup_s: rng.range_f64(10.0, 600.0),
    }
}

/// A random ledger with irregular spans, PG samples, and capacity steps.
fn random_ledger(rng: &mut Rng) -> (Ledger, f64) {
    let mut ledger = Ledger::new();
    ledger.set_capacity(0.0, rng.range_u64(500, 50_000));
    let end = rng.range_f64(1_000.0, 20_000.0);
    if rng.chance(0.7) {
        let t = rng.range_f64(0.0, end);
        ledger.set_capacity(t, rng.range_u64(500, 50_000));
    }
    let n_jobs = rng.range_u64(1, 20);
    for id in 1..=n_jobs {
        let job = random_job(rng, id);
        let chips = job.chips();
        ledger.ensure_job(JobMeta::of(&job));
        let mut t = rng.range_f64(0.0, end * 0.5);
        for _ in 0..rng.range_u64(0, 25) {
            let dur = rng.range_f64(0.1, end * 0.1);
            let class = TimeClass::ALL[rng.below(7) as usize];
            // Half default layer tags, half explicit random layers — the
            // per-layer cells must stay bit-identical across paths even
            // when a class splits across layers (the engine's
            // compile-vs-restore / data-vs-framework refinements).
            if rng.chance(0.5) {
                ledger.add_span_auto(id, t, t + dur, chips, class);
            } else {
                let layer = StackLayer::ALL[rng.below(6) as usize];
                ledger.add_span(id, t, t + dur, chips, class, layer);
            }
            if class == TimeClass::Productive && rng.chance(0.8) {
                ledger.add_pg_sample(id, t, t + dur, chips, rng.range_f64(0.0, 1.0));
            }
            t += dur * rng.range_f64(0.8, 1.4);
        }
    }
    (ledger, end)
}

use tpufleet::testkit::assert_reports_bit_identical as assert_bitwise;

/// Single-pass `report` == naive reference == retained AoS-walk
/// reference, bit for bit, under random ledgers, windows, and meta
/// filters. Three-way on purpose: `report` now sweeps the SoA columns
/// chunk-wise, `report_ref` reassembles per-span structs the pre-SoA
/// way, and `report_naive` rescans per class — all over the same
/// column storage.
#[test]
fn prop_single_pass_report_matches_naive() {
    check(80, 0x5EDC, |rng| {
        let (ledger, end) = random_ledger(rng);
        for _ in 0..4 {
            let a = rng.range_f64(0.0, end);
            let b = rng.range_f64(0.0, end);
            let (w0, w1) = (a.min(b), a.max(b));
            let fast = goodput::report(&ledger, w0, w1, |_| true);
            assert_bitwise(
                &fast,
                &goodput::report_naive(&ledger, w0, w1, |_| true),
                &format!("fleet [{w0}, {w1})"),
            );
            assert_bitwise(
                &fast,
                &goodput::report_ref(&ledger, w0, w1, |_| true),
                &format!("fleet AoS ref [{w0}, {w1})"),
            );
            let phase = [Phase::Training, Phase::Serving, Phase::BulkInference]
                [rng.below(3) as usize];
            let fast = goodput::report(&ledger, w0, w1, |m| m.phase == phase);
            assert_bitwise(
                &fast,
                &goodput::report_naive(&ledger, w0, w1, |m| m.phase == phase),
                &format!("{} [{w0}, {w1})", phase.name()),
            );
            assert_bitwise(
                &fast,
                &goodput::report_ref(&ledger, w0, w1, |m| m.phase == phase),
                &format!("{} AoS ref [{w0}, {w1})", phase.name()),
            );
        }
    });
}

/// Single-pass `segmented` == naive reference on every axis.
#[test]
fn prop_single_pass_segmented_matches_naive() {
    let axes =
        [Axis::Phase, Axis::Framework, Axis::Arch, Axis::Generation, Axis::SizeClass];
    check(40, 0x5E63, |rng| {
        let (ledger, end) = random_ledger(rng);
        let axis = axes[rng.below(axes.len() as u64) as usize];
        let fast = goodput::segmented(&ledger, 0.0, end, axis);
        let slow = goodput::segmented_naive(&ledger, 0.0, end, axis);
        assert_eq!(fast.len(), slow.len(), "{axis:?}: row count");
        for (f, s) in fast.iter().zip(&slow) {
            assert_eq!(f.label, s.label, "{axis:?}");
            assert_bitwise(&f.report, &s.report, &f.label);
        }
    });
}

/// One-fold `TimeSeries::build` == per-window naive reference == the
/// retained AoS-walk fold (`build_ref`) — the multi-window shape of the
/// chunked-SoA-vs-reference property.
#[test]
fn prop_single_pass_series_matches_naive() {
    check(40, 0x5E71E5, |rng| {
        let (ledger, end) = random_ledger(rng);
        let width = rng.range_f64(end / 30.0, end / 2.0);
        let fast = TimeSeries::build("t", &ledger, 0.0, end, width, |_| true);
        let slow = TimeSeries::build_naive("t", &ledger, 0.0, end, width, |_| true);
        let aos = TimeSeries::build_ref("t", &ledger, 0.0, end, width, |_| true);
        assert_eq!(fast.windows.len(), slow.windows.len());
        assert_eq!(fast.windows.len(), aos.windows.len());
        for ((f, s), w) in fast.reports.iter().zip(&slow.reports).zip(&fast.windows) {
            assert_bitwise(f, s, &format!("window [{}, {})", w.t0, w.t1));
        }
        for ((f, a), w) in fast.reports.iter().zip(&aos.reports).zip(&fast.windows) {
            assert_bitwise(f, a, &format!("AoS ref window [{}, {})", w.t0, w.t1));
        }
    });
}

/// Every `TimeClass` × `StackLayer` combination survives the one-byte
/// span columns: spans written through the public ledger API read back
/// with their exact class and layer (the integration-level mirror of
/// the `index()`/`from_index()` unit round-trips), and the per-class /
/// per-layer totals land in the right buckets.
#[test]
fn soa_columns_round_trip_every_class_layer_combination() {
    let mut ledger = Ledger::new();
    ledger.set_capacity(0.0, 10_000);
    let job = random_job(&mut Rng::new(0xC01), 1);
    ledger.ensure_job(JobMeta::of(&job));
    let mut t = 0.0;
    let mut written = Vec::new();
    for &class in TimeClass::ALL.iter() {
        for &layer in StackLayer::ALL.iter() {
            ledger.add_span(1, t, t + 5.0, 8, class, layer);
            written.push((t, class, layer));
            t += 10.0;
        }
    }
    let jl = &ledger.jobs[&1].1;
    assert_eq!(jl.spans.len(), TimeClass::ALL.len() * StackLayer::ALL.len());
    for ((t0, class, layer), got) in written.iter().zip(jl.spans.iter()) {
        assert_eq!(got.t0.to_bits(), t0.to_bits());
        assert_eq!(got.class, *class, "class at t0={t0}");
        assert_eq!(got.layer, *layer, "layer at t0={t0}");
    }
    // Bucket placement: each layer holds exactly its written piece sum,
    // chunked fold vs naive per-layer rescan, bitwise.
    let report = goodput::report(&ledger, 0.0, t, |_| true);
    for (i, layer) in StackLayer::ALL.iter().enumerate() {
        let naive = ledger.layer_chip_seconds(*layer, 0.0, t, |_| true);
        assert_eq!(report.layer_cs[i].to_bits(), naive.to_bits(), "{}", layer.name());
        assert_eq!(naive, TimeClass::ALL.len() as f64 * 5.0 * 8.0, "{}", layer.name());
    }
}

fn sweep_spec(workers: usize) -> SweepSpec {
    let mut spec = SweepSpec::new().workers(workers);
    for (i, seed) in [3u64, 11, 17].iter().enumerate() {
        let mut cfg = SimConfig {
            seed: *seed,
            duration_s: 10.0 * 3600.0,
            static_fleet: vec![(ChipGeneration::TpuC, 12)],
            ..Default::default()
        };
        cfg.generator.arrivals_per_hour = 10.0;
        cfg.generator.gen_mix = vec![(ChipGeneration::TpuC, 1.0)];
        if i == 1 {
            cfg.policy.preemption = false;
        }
        spec.push(format!("v{i}"), cfg);
    }
    spec
}

/// Windowed-ledger sweep summaries == full-ledger summaries, bit for bit,
/// on real simulations (failures, preemptions, queueing included).
#[test]
fn windowed_sweep_summaries_match_full_ledger_bitwise() {
    let mut full: Vec<SweepSummary> = Vec::new();
    SweepRunner::run_streaming_summaries_with_mode(
        sweep_spec(2),
        None,
        LedgerMode::Full,
        |s| full.push(s),
    );
    let mut win: Vec<SweepSummary> = Vec::new();
    SweepRunner::run_streaming_summaries(sweep_spec(2), None, |s| win.push(s));
    assert_eq!(full.len(), win.len());
    for (f, w) in full.iter().zip(&win) {
        assert_eq!(f.name, w.name);
        assert_eq!(f.result, w.result, "{}", f.name);
        assert_bitwise(&f.goodput, &w.goodput, &f.name);
    }
}

/// End-to-end byte identity: the sweep report written from windowed-mode
/// summaries is byte-identical to the one written from full-ledger
/// summaries — the in-process mirror of the CI `cmp` gate, covering the
/// shared row/report writers too.
#[test]
fn sweep_report_bytes_identical_across_ledger_modes() {
    use tpufleet::util::Json;

    let spec_json = Json::obj(vec![("grid", Json::str("mode-cmp"))]);
    let write = |mode: LedgerMode| -> Vec<u8> {
        let mut out: Vec<u8> = Vec::new();
        shard::write_report_header(&mut out, &spec_json).unwrap();
        let mut n = 0usize;
        SweepRunner::run_streaming_summaries_with_mode(sweep_spec(1), None, mode, |s| {
            shard::write_report_row(&mut out, n, &shard::summary_row_json(&s)).unwrap();
            n += 1;
        });
        shard::write_report_footer(&mut out).unwrap();
        out
    };
    let full = write(LedgerMode::Full);
    let windowed = write(tpufleet::sim::sweep::summary_ledger_mode());
    assert_eq!(
        String::from_utf8(full).unwrap(),
        String::from_utf8(windowed).unwrap(),
        "report bytes must not depend on the accounting mode"
    );
}

/// Per-layer cells, not just per-class: the single-pass fold's layer
/// buckets must be bit-identical to one naive rescan per layer
/// (`Ledger::layer_chip_seconds`) AND to a streaming windowed ledger fed
/// the identical spans — for random ledgers, random windows, and meta
/// filters. (`assert_bitwise` also re-checks layers inside every other
/// property in this suite, since the report carries `layer_cs`.)
#[test]
fn prop_layer_cells_bitwise_across_naive_single_pass_and_windowed() {
    check(60, 0x1A9E2, |rng| {
        // Twin ledgers: every write (capacity, layered spans, PG samples)
        // mirrored into a full-span ledger and a streaming windowed one,
        // with a width chosen so windows straddle span boundaries.
        let end = rng.range_f64(1_000.0, 20_000.0);
        let width = rng.range_f64(end / 20.0, end / 2.0);
        let mut ledger = Ledger::new();
        let mut win = WindowedLedger::new(end, width);
        let c0 = rng.range_u64(500, 50_000);
        ledger.set_capacity(0.0, c0);
        win.set_capacity(0.0, c0);
        if rng.chance(0.7) {
            let t = rng.range_f64(0.0, end);
            let c = rng.range_u64(500, 50_000);
            ledger.set_capacity(t, c);
            win.set_capacity(t, c);
        }
        let n_jobs = rng.range_u64(1, 15);
        for id in 1..=n_jobs {
            let job = random_job(rng, id);
            let chips = job.chips();
            let meta = JobMeta::of(&job);
            ledger.ensure_job(meta.clone());
            win.ensure_job(meta);
            let mut t = rng.range_f64(0.0, end * 0.5);
            for _ in 0..rng.range_u64(0, 20) {
                let dur = rng.range_f64(0.1, end * 0.1);
                let class = TimeClass::ALL[rng.below(7) as usize];
                let layer = StackLayer::ALL[rng.below(6) as usize];
                ledger.add_span(id, t, t + dur, chips, class, layer);
                win.add_span(id, t, t + dur, chips, class, layer);
                if class == TimeClass::Productive && rng.chance(0.8) {
                    let pg = rng.range_f64(0.0, 1.0);
                    ledger.add_pg_sample(id, t, t + dur, chips, pg);
                    win.add_pg_sample(id, t, t + dur, chips, pg);
                }
                t += dur * rng.range_f64(0.8, 1.4);
            }
        }
        // Whole horizon, fleet and filtered: fold vs naive per-layer
        // rescans vs the windowed ledger.
        let phase = [Phase::Training, Phase::Serving, Phase::BulkInference]
            [rng.below(3) as usize];
        let filters: [(&str, Box<dyn Fn(&JobMeta) -> bool>); 2] = [
            ("fleet", Box::new(|_| true)),
            ("phase", Box::new(move |m: &JobMeta| m.phase == phase)),
        ];
        for (what, filter) in &filters {
            let fast = goodput::report(&ledger, 0.0, end, filter);
            for (i, layer) in StackLayer::ALL.iter().enumerate() {
                let naive = ledger.layer_chip_seconds(*layer, 0.0, end, filter);
                assert_eq!(
                    fast.layer_cs[i].to_bits(),
                    naive.to_bits(),
                    "{what}: fold vs naive layer {}",
                    layer.name()
                );
            }
            assert_bitwise(&win.report(filter), &fast, &format!("{what}: windowed"));
        }
        // Per-window cells too (the windowed series reports carry the
        // layer buckets through assert_bitwise).
        let ws = win.series("w", |_| true);
        let fs = TimeSeries::build("w", &ledger, 0.0, end, width, |_| true);
        assert_eq!(ws.windows.len(), fs.windows.len());
        for (i, (a, b)) in ws.reports.iter().zip(&fs.reports).enumerate() {
            assert_bitwise(a, b, &format!("window {i}"));
        }
    });
}

/// A CACHE_VERSION-2 entry (pre-attribution: no `layer_cs`, old version
/// stamp) must read as a MISS — the variant silently re-simulates — not
/// as corruption and not as a layerless report.
#[test]
fn cache_v2_entries_read_as_misses_not_corruption() {
    use tpufleet::sim::{CacheKey, SweepCache};
    use tpufleet::util::Json;

    let dir = std::env::temp_dir().join(format!("tpufleet-cache-v2-{}", std::process::id()));
    let cache = SweepCache::new(&dir);
    cache.clear().expect("clearing temp cache");

    let mut spec = SweepSpec::new().workers(1);
    let cfg = sweep_spec(1).variants[0].cfg.clone();
    spec.push("solo", cfg.clone());
    let mut first: Vec<SweepSummary> = Vec::new();
    SweepRunner::run_streaming_summaries(spec, Some(&cache), |s| first.push(s));
    assert!(!first[0].cached, "cold start must simulate");

    // Forge the entry down to a v2-era shape.
    let path = dir.join(CacheKey::of(&cfg).file_name());
    let text = std::fs::read_to_string(&path).expect("entry must exist");
    let mut entry = Json::parse(&text).unwrap();
    if let Json::Obj(ref mut o) = entry {
        o.insert("version".into(), Json::num(2.0));
        if let Some(Json::Obj(g)) = o.get_mut("goodput") {
            g.remove("layer_cs");
        }
    }
    std::fs::write(&path, entry.to_string_pretty()).unwrap();

    let mut spec = SweepSpec::new().workers(1);
    spec.push("solo", cfg);
    let mut second: Vec<SweepSummary> = Vec::new();
    SweepRunner::run_streaming_summaries(spec, Some(&cache), |s| second.push(s));
    assert!(!second[0].cached, "v2 entry must read as a miss, not serve");
    assert_eq!(first[0].result, second[0].result);
    assert_bitwise(&first[0].goodput, &second[0].goodput, "re-simulated summary");
    cache.clear().unwrap();
}

/// The incremental `end_time` tracker never drifts from the span fold.
#[test]
fn prop_end_time_matches_fold() {
    check(60, 0xE2D, |rng| {
        let (ledger, _) = random_ledger(rng);
        assert_eq!(
            ledger.end_time().to_bits(),
            ledger.end_time_by_fold().to_bits(),
            "incremental max-end drifted from the span fold"
        );
    });
}
