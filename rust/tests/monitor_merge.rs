//! Fleet-dashboard contracts: the N-stream live merge (bounded reorder
//! buffers, arbitrary per-stream lag) must ingest into a report and
//! snapshot `f64::to_bits`-identical to batch-replaying the
//! watermark-ordered interleaving through one [`MonitorLedger`], for
//! N ∈ {1, 2, 5}; and the `monitor --merge` / `--listen` CLI must hold
//! the same byte-identity on the real binary, with `GET /snapshot`
//! serving exactly the `--out` file's bytes at the same watermark.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::{Arc, Mutex};

use tpufleet::monitor::merge::{self, StreamMerger};
use tpufleet::monitor::proto::{Event, StreamRecorder, Validator};
use tpufleet::monitor::{snapshot_json, MonitorLedger, StreamStats};
use tpufleet::sim::{SimConfig, Simulation};
use tpufleet::testkit::assert_reports_bit_identical;
use tpufleet::util::Json;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_tpufleet")
}

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("tpufleet-monitor-merge-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("creating scratch dir");
    dir
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Record one cell's simulation stream as parsed, validated events.
fn recorded_events(seed: u64, days: f64) -> Vec<Event> {
    let mut cfg = SimConfig { seed, duration_s: days * 86400.0, ..Default::default() };
    cfg.generator.arrivals_per_hour = 8.0;
    let buf = Arc::new(Mutex::new(String::new()));
    let mut sim = Simulation::new(cfg).ledger_mode(tpufleet::sim::sweep::summary_ledger_mode());
    sim.attach_sink(Box::new(StreamRecorder::sharing(buf.clone())));
    sim.run();
    let text = buf.lock().unwrap().clone();
    let mut validator = Validator::default();
    let mut evs = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(ev) = Event::parse(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1)) {
            validator.check(&ev).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
            evs.push(ev);
        }
    }
    evs
}

fn names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("cell-{i}")).collect()
}

fn replay(evs: &[Event], width_s: f64, ring: usize) -> MonitorLedger {
    let mut ml = MonitorLedger::new(width_s, ring);
    for ev in evs {
        ml.ingest(ev);
    }
    ml
}

fn snapshot_bytes(ml: &MonitorLedger) -> String {
    let stats = StreamStats {
        jobs: ml.job_count(),
        spans: ml.span_count(),
        pg_samples: ml.pg_count(),
        cap_events: ml.cap_events(),
    };
    snapshot_json(&ml.report(|_| true), ml.watermark_s(), ml.width_s(), &stats, true)
        .to_string_pretty()
}

/// Live-pump the merge under an adversarial schedule: every stream but
/// `laggard` is fed greedily (up to the reorder cap); the laggard only
/// receives ONE event each time the merge is completely stalled on it.
/// Returns the emitted sequence plus the final per-stream telemetry.
fn pump_with_lag(
    streams: &[Vec<Event>],
    cap: usize,
    laggard: usize,
) -> (Vec<Event>, Vec<merge::StreamInfo>, usize) {
    let mut m = StreamMerger::new(&names(streams.len()), cap);
    let mut idx = vec![0usize; streams.len()];
    let mut fed_done = vec![false; streams.len()];
    let mut out = Vec::new();
    let mut stalls = 0usize;
    loop {
        let mut progressed = false;
        for (s, stream) in streams.iter().enumerate() {
            if s == laggard {
                continue;
            }
            while m.wants(s) && idx[s] < stream.len() {
                m.push(s, stream[idx[s]].clone());
                idx[s] += 1;
                progressed = true;
            }
            if idx[s] == stream.len() && !fed_done[s] {
                m.finish(s);
                fed_done[s] = true;
                progressed = true;
            }
        }
        while let Some(ev) = m.pop() {
            out.push(ev);
            progressed = true;
        }
        if m.done() {
            break;
        }
        if !progressed {
            // Only the laggard can unblock the merge now.
            stalls += 1;
            if idx[laggard] < streams[laggard].len() {
                m.push(laggard, streams[laggard][idx[laggard]].clone());
                idx[laggard] += 1;
            } else {
                assert!(!fed_done[laggard], "stalled with every stream exhausted");
                m.finish(laggard);
                fed_done[laggard] = true;
            }
        }
    }
    let infos = m.infos();
    (out, infos, stalls)
}

#[test]
fn merged_stream_is_bit_identical_to_batch_interleave_for_n_1_2_5() {
    const WIDTH_S: f64 = 1800.0;
    const RING: usize = 8;
    const CAP: usize = 16;
    for n in [1usize, 2, 5] {
        let streams: Vec<Vec<Event>> =
            (0..n).map(|i| recorded_events(0x3000 + i as u64, 0.25)).collect();
        // Batch reference: the watermark-ordered interleaving of the
        // complete streams through one ledger.
        let reference = merge::interleave(&names(n), streams.clone());
        // The merged sequence is itself a valid stream: remapped ids are
        // declared before use and merged cap times never decrease.
        let mut validator = Validator::labeled("merged");
        for ev in &reference {
            validator.check(ev).expect("merged stream must validate");
        }
        let batch = replay(&reference, WIDTH_S, RING);
        // Live pump: bounded buffers, stream 0 delayed arbitrarily.
        let (live_seq, infos, stalls) = pump_with_lag(&streams, CAP, 0);
        assert_eq!(live_seq.len(), reference.len(), "N={n}");
        for (a, b) in live_seq.iter().zip(&reference) {
            assert_eq!(a.format(), b.format(), "N={n}: schedule changed the merge order");
        }
        let live = replay(&live_seq, WIDTH_S, RING);
        assert!(live.evicted_cells() > 0, "N={n}: a 6h stream must overflow a 4h ring");
        assert_reports_bit_identical(&batch.report(|_| true), &live.report(|_| true), "fleet");
        assert_eq!(snapshot_bytes(&batch), snapshot_bytes(&live), "N={n} snapshot bytes");
        if n > 1 {
            assert!(stalls > 0, "N={n}: the delayed stream must stall the merge");
            assert!(
                infos.iter().any(|i| i.peak_buffered == CAP),
                "N={n}: some prompt stream must fill its reorder buffer \
                 (peaks: {:?})",
                infos.iter().map(|i| i.peak_buffered).collect::<Vec<_>>()
            );
            assert!(
                infos.iter().all(|i| i.peak_buffered <= CAP),
                "N={n}: no buffer may exceed the bound"
            );
        }
    }
}

#[test]
fn merge_cli_snapshot_matches_merge_batch_bytewise() {
    let dir = scratch("cli");
    let mut stream_args = String::new();
    for (i, seed) in [0x51u64, 0x52, 0x53].iter().enumerate() {
        let out = dir.join(format!("cell{i}.txt"));
        let ok = Command::new(bin())
            .args(["monitor", "record", "--days", "0.1", "--arrivals-per-hour", "6"])
            .args(["--seed", &seed.to_string()])
            .args(["--stream-id", &format!("cell-{i}")])
            .args(["--out", &out.display().to_string()])
            .status()
            .expect("spawning tpufleet")
            .success();
        assert!(ok, "monitor record failed");
        if i > 0 {
            stream_args.push(',');
        }
        stream_args.push_str(&out.display().to_string());
    }
    let live = dir.join("merged_live.json");
    let batch = dir.join("merged_batch.json");
    for (flag, out) in [(None, &live), (Some("--batch"), &batch)] {
        let mut cmd = Command::new(bin());
        cmd.args(["monitor", "--merge", "--in", &stream_args]);
        cmd.args(["--width-s", "900", "--ring-windows", "4", "--reorder-cap", "32"]);
        if let Some(flag) = flag {
            cmd.arg(flag);
        }
        cmd.args(["--out", &out.display().to_string()]);
        let output = cmd.output().expect("spawning tpufleet");
        assert!(
            output.status.success(),
            "monitor --merge failed: {}",
            String::from_utf8_lossy(&output.stderr)
        );
    }
    assert_eq!(read(&live), read(&batch), "live merge vs batch interleave snapshot bytes");
    let doc = Json::parse(&read(&live)).expect("merged snapshot parses");
    assert_eq!(doc.get("final").as_bool(), Some(true));
    assert!(doc.get("fleet").get("mpg").as_f64().is_some());
}

/// Issue one HTTP GET against the dashboard and return (status line, body).
fn http_get(addr: &str, path: &str) -> (String, String) {
    use std::io::{Read as _, Write as _};
    let mut conn = std::net::TcpStream::connect(addr).expect("connecting to dashboard");
    write!(conn, "GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).expect("reading response");
    let (head, body) = response.split_once("\r\n\r\n").expect("header/body split");
    (head.lines().next().unwrap_or("").to_string(), body.to_string())
}

#[test]
fn listen_endpoint_serves_the_snapshot_file_bytes() {
    use std::io::BufRead as _;
    let dir = scratch("listen");
    let stream_path = dir.join("stream.txt");
    let snap_path = dir.join("snap.json");
    // A finished recorded stream, minus the `end` line so the follower
    // keeps serving while we probe the endpoints.
    let record_ok = Command::new(bin())
        .args(["monitor", "record", "--days", "0.1", "--seed", "77", "--arrivals-per-hour", "6"])
        .args(["--out", &stream_path.display().to_string()])
        .status()
        .expect("spawning tpufleet")
        .success();
    assert!(record_ok);
    let full = read(&stream_path);
    let partial: String = full.lines().filter(|l| *l != "end").map(|l| format!("{l}\n")).collect();
    std::fs::write(&stream_path, &partial).unwrap();
    let mut child = Command::new(bin())
        .args(["monitor", "--in", &stream_path.display().to_string(), "--follow"])
        .args(["--width-s", "900", "--ring-windows", "4", "--snapshot-every", "600"])
        .args(["--listen", "127.0.0.1:0"])
        .args(["--out", &snap_path.display().to_string()])
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawning follower");
    // The ephemeral port is announced on stderr.
    let mut stderr = std::io::BufReader::new(child.stderr.take().expect("piped stderr"));
    let addr = loop {
        let mut line = String::new();
        assert!(stderr.read_line(&mut line).unwrap() > 0, "follower exited before listening");
        if let Some(rest) = line.trim().strip_prefix("monitor: dashboard listening on http://") {
            break rest.to_string();
        }
    };
    // Once the follower idles at EOF, the last emit wrote --out and the
    // dashboard cache from the same rendered string: poll until the
    // endpoint serves exactly the file's bytes.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    let body = loop {
        assert!(std::time::Instant::now() < deadline, "endpoint never matched the file");
        std::thread::sleep(std::time::Duration::from_millis(150));
        let (status, body) = http_get(&addr, "/snapshot");
        assert!(status.contains("200"), "{status}");
        if !body.is_empty() && snap_path.exists() && body == read(&snap_path) {
            break body;
        }
    };
    let doc = Json::parse(&body).expect("snapshot JSON parses");
    assert_eq!(doc.get("final").as_bool(), Some(false));
    assert!(doc.get("fleet").get("mpg").as_f64().is_some());
    // The other endpoints serve well-formed documents too.
    let (status, streams) = http_get(&addr, "/streams");
    assert!(status.contains("200"), "{status}");
    let streams = Json::parse(&streams).expect("streams JSON parses");
    assert_eq!(streams.get("stream_count").as_f64(), Some(1.0));
    let (status, series) = http_get(&addr, "/series");
    assert!(status.contains("200"), "{status}");
    assert!(Json::parse(&series).expect("series JSON parses").get("windows").as_arr().is_some());
    let (status, _) = http_get(&addr, "/nope");
    assert!(status.contains("404"), "{status}");
    // Land the `end` line: the follower finishes and writes the final
    // snapshot, which must match a one-shot replay of the full stream.
    std::fs::write(&stream_path, &full).unwrap();
    let status = child.wait().expect("waiting for follower");
    assert!(status.success());
    let once_path = dir.join("snap_once.json");
    let full_path = dir.join("full.txt");
    std::fs::write(&full_path, &full).unwrap();
    let ok = Command::new(bin())
        .args(["monitor", "--in", &full_path.display().to_string()])
        .args(["--width-s", "900", "--ring-windows", "4"])
        .args(["--out", &once_path.display().to_string()])
        .status()
        .expect("spawning tpufleet")
        .success();
    assert!(ok);
    assert_eq!(read(&snap_path), read(&once_path), "final follow snapshot vs one-shot replay");
}
