//! Integration: the real PJRT path — AOT HLO artifacts loaded, compiled,
//! and executed from Rust, numerics checked, measured-PG pipeline
//! exercised. Skips cleanly if `make artifacts` hasn't run.

use std::path::PathBuf;

use tpufleet::fleet::ChipGeneration;
use tpufleet::roofline;
use tpufleet::runtime::{corpus, Engine, Manifest, Trainer};
use tpufleet::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn matmul_artifact_matches_host_matmul() {
    let dir = require_artifacts!();
    let mut engine = Engine::new(&dir).unwrap();
    let mut rng = Rng::new(3);
    let n = 256;
    let a: Vec<f32> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let la = Engine::literal_f32(&a, &[n, n]).unwrap();
    let lb = Engine::literal_f32(&b, &[n, n]).unwrap();
    let outs = engine.execute("matmul_pallas", &[la, lb]).unwrap();
    let got = outs[0].to_vec::<f32>().unwrap();

    // Host reference for a few random entries (full n^3 check is slow in
    // a debug test binary).
    let mut check_rng = Rng::new(4);
    for _ in 0..50 {
        let i = check_rng.below(n as u64) as usize;
        let j = check_rng.below(n as u64) as usize;
        let mut want = 0f64;
        for k in 0..n {
            want += a[i * n + k] as f64 * b[k * n + j] as f64;
        }
        let gotv = got[i * n + j] as f64;
        assert!(
            (gotv - want).abs() < 1e-3 * (1.0 + want.abs()),
            "({i},{j}): {gotv} vs {want}"
        );
    }
}

#[test]
fn mlp_fused_and_naive_agree_numerically_but_not_in_speed() {
    let dir = require_artifacts!();
    let mut engine = Engine::new(&dir).unwrap();
    let spec = engine.manifest.artifact("mlp_fused").unwrap().clone();
    let mut rng = Rng::new(5);
    let inputs: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|t| {
            let v: Vec<f32> =
                (0..t.elements()).map(|_| rng.range_f64(-0.5, 0.5) as f32).collect();
            Engine::literal_f32(&v, &t.shape).unwrap()
        })
        .collect();
    let clone_inputs = |src: &[xla::Literal]| -> Vec<xla::Literal> {
        src.iter()
            .zip(&spec.inputs)
            .map(|(l, t)| {
                let v = l.to_vec::<f32>().unwrap();
                Engine::literal_f32(&v, &t.shape).unwrap()
            })
            .collect()
    };

    let fused = engine.execute("mlp_fused", &clone_inputs(&inputs)).unwrap();
    let naive = engine.execute("mlp_naive", &clone_inputs(&inputs)).unwrap();
    let fv = fused[0].to_vec::<f32>().unwrap();
    let nv = naive[0].to_vec::<f32>().unwrap();
    assert_eq!(fv.len(), nv.len());
    for (i, (x, y)) in fv.iter().zip(&nv).enumerate() {
        assert!((x - y).abs() < 2e-2 * (1.0 + y.abs()), "elem {i}: {x} vs {y}");
    }

    // The Fig. 12 PG premise measured for real: same useful FLOPs per the
    // unoptimized-graph analysis, very different actual time.
    let cost_f = engine.module_cost("mlp_fused").unwrap();
    let cost_n = engine.module_cost("mlp_naive").unwrap();
    let ratio = cost_n.flops / cost_f.flops;
    assert!(
        (0.3..3.5).contains(&ratio),
        "useful-FLOPs should be same order: {ratio}"
    );

    let time = |engine: &mut Engine, name: &str| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let (_o, dt) = engine.execute_timed(name, &clone_inputs(&inputs)).unwrap();
            best = best.min(dt);
        }
        best
    };
    let t_fused = time(&mut engine, "mlp_fused");
    let t_naive = time(&mut engine, "mlp_naive");
    eprintln!("fused {:.3} ms vs naive {:.3} ms", t_fused * 1e3, t_naive * 1e3);
    assert!(
        t_naive > 1.5 * t_fused,
        "naive ({t_naive}s) should be much slower than fused ({t_fused}s)"
    );

    // And therefore measured PG orders correctly on the same roofline.
    let cpu = ChipGeneration::Cpu.spec();
    let pg_fused = roofline::program_goodput(
        roofline::estimate(&cost_f, cpu, false).ideal_compute_s,
        t_fused,
    );
    let pg_naive = roofline::program_goodput(
        roofline::estimate(&cost_n, cpu, false).ideal_compute_s,
        t_naive,
    );
    eprintln!("PG fused {pg_fused:.4} vs naive {pg_naive:.4}");
    assert!(pg_fused > pg_naive, "{pg_fused} vs {pg_naive}");
}

#[test]
fn infer_step_runs_and_is_deterministic() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let mut trainer = Trainer::new(engine, 7).unwrap();
    let a1 = trainer.eval_next_token_accuracy().unwrap();
    assert!((0.0..=1.0).contains(&a1));
}

#[test]
fn short_training_run_reduces_loss() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let mut trainer = Trainer::new(engine, 1).unwrap();
    let report = trainer.train(40, 0.3, 0).unwrap();
    assert_eq!(report.steps, 40);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    // First loss near the uniform floor ln(256) ≈ 5.55.
    assert!(report.first_loss() > 4.0 && report.first_loss() < 7.5);
    // Mean of last 5 losses well below the first.
    let tail: f32 = report.losses[35..].iter().sum::<f32>() / 5.0;
    assert!(
        tail < report.first_loss() - 1.0,
        "loss should drop: {} -> {tail}",
        report.first_loss()
    );
    assert!(report.mean_step_seconds() > 0.0);
}

#[test]
fn train_step_cost_analysis_supports_measured_pg() {
    let dir = require_artifacts!();
    let engine = Engine::new(&dir).unwrap();
    let cost = engine.module_cost("train_step").unwrap();
    assert!(cost.flops > 1e8);
    // The dominant opcode must be dot (a transformer's matmuls).
    let dot = cost.by_opcode.get("dot").copied().unwrap_or(0.0);
    assert!(dot > 0.9 * cost.flops, "dot share {}", dot / cost.flops);
}

#[test]
fn corpus_is_deterministic_per_seed() {
    let mut a = Rng::new(9);
    let mut b = Rng::new(9);
    assert_eq!(corpus::generate(&mut a, 1024), corpus::generate(&mut b, 1024));
}

#[test]
fn manifest_io_contract_holds() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let train = m.artifact("train_step").unwrap();
    let infer = m.artifact("infer_step").unwrap();
    // Same parameter prefix in both artifacts.
    for (a, b) in train.inputs.iter().zip(infer.inputs.iter()) {
        if a.name == "tokens" {
            break;
        }
        assert_eq!(a, b, "param prefix mismatch");
    }
}
