//! Crash/resume contracts: a monitor checkpointed at ANY event index and
//! resumed must produce `f64::to_bits`-identical reports and snapshot
//! bytes to the uninterrupted run, for 1-, 2-, and 5-stream merges
//! (property-tested over random cut points); and on the real binary a
//! `monitor --checkpoint` killed by an injected `monitor-exit` fault must
//! `--resume` to a final snapshot byte-identical to a run that never
//! died, while `--merge --quarantine` must survive a garbled stream that
//! kills strict mode.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::{Arc, Mutex};

use tpufleet::monitor::merge;
use tpufleet::monitor::proto::{Event, StreamRecorder, Validator};
use tpufleet::monitor::{snapshot_json, MonitorLedger, StreamStats};
use tpufleet::sim::{SimConfig, Simulation};
use tpufleet::testkit::{assert_reports_bit_identical, check};
use tpufleet::util::fault::INJECTED_EXIT_CODE;
use tpufleet::util::Json;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_tpufleet")
}

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("tpufleet-monitor-ckpt-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("creating scratch dir");
    dir
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Record one cell's simulation stream as parsed, validated events.
fn recorded_events(seed: u64, days: f64) -> Vec<Event> {
    let mut cfg = SimConfig { seed, duration_s: days * 86400.0, ..Default::default() };
    cfg.generator.arrivals_per_hour = 8.0;
    let buf = Arc::new(Mutex::new(String::new()));
    let mut sim = Simulation::new(cfg).ledger_mode(tpufleet::sim::sweep::summary_ledger_mode());
    sim.attach_sink(Box::new(StreamRecorder::sharing(buf.clone())));
    sim.run();
    let text = buf.lock().unwrap().clone();
    let mut validator = Validator::default();
    let mut evs = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(ev) = Event::parse(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1)) {
            validator.check(&ev).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
            evs.push(ev);
        }
    }
    evs
}

fn snapshot_bytes(ml: &MonitorLedger) -> String {
    let stats = StreamStats {
        jobs: ml.job_count(),
        spans: ml.span_count(),
        pg_samples: ml.pg_count(),
        cap_events: ml.cap_events(),
    };
    snapshot_json(&ml.report(|_| true), ml.watermark_s(), ml.width_s(), &stats, true)
        .to_string_pretty()
}

/// Satellite (d): checkpoint/restore at a RANDOM event index — through
/// the full serialize -> bytes -> parse -> restore path, exactly what a
/// crash-and-`--resume` exercises — then ingest the rest into both the
/// original and the restored ledger. Reports, watermarks, and rendered
/// snapshot bytes must come out bit-identical to a run that never
/// stopped, for N ∈ {1, 2, 5} merged streams.
#[test]
fn checkpoint_at_any_event_index_resumes_bit_identically() {
    const WIDTH_S: f64 = 1800.0;
    const RING: usize = 6;
    for n in [1usize, 2, 5] {
        let names: Vec<String> = (0..n).map(|i| format!("cell-{i}")).collect();
        let streams: Vec<Vec<Event>> =
            (0..n).map(|i| recorded_events(0x9100 + i as u64, 0.2)).collect();
        let reference = merge::interleave(&names, streams);
        let mut full = MonitorLedger::new(WIDTH_S, RING);
        let mut full_validator = Validator::labeled("merged");
        for ev in &reference {
            full_validator.check(ev).expect("merged stream validates");
            full.ingest(ev);
        }
        let want = snapshot_bytes(&full);
        let total = reference.len() as u64;
        check(12, 0x51EE_D000 + n as u64, |rng| {
            let cut = rng.below(total + 1) as usize;
            let mut ml = MonitorLedger::new(WIDTH_S, RING);
            let mut validator = Validator::labeled("merged");
            for ev in &reference[..cut] {
                validator.check(ev).unwrap();
                ml.ingest(ev);
            }
            let ledger_text = ml.ckpt_json().to_string_pretty();
            let validator_text = validator.ckpt_json().to_string_pretty();
            let mut resumed =
                MonitorLedger::from_ckpt(&Json::parse(&ledger_text).unwrap()).unwrap();
            let mut resumed_validator =
                Validator::from_ckpt(&Json::parse(&validator_text).unwrap()).unwrap();
            for ev in &reference[cut..] {
                resumed_validator.check(ev).unwrap_or_else(|e| {
                    panic!("N={n} cut={cut}: restored validator rejected the tail: {e}")
                });
                resumed.ingest(ev);
                ml.ingest(ev);
            }
            assert_reports_bit_identical(
                &full.report(|_| true),
                &resumed.report(|_| true),
                &format!("N={n} cut={cut}"),
            );
            assert_eq!(
                full.watermark_s().to_bits(),
                resumed.watermark_s().to_bits(),
                "N={n} cut={cut}: watermark"
            );
            assert_eq!(want, snapshot_bytes(&resumed), "N={n} cut={cut}: snapshot bytes");
            assert_eq!(
                snapshot_bytes(&ml),
                snapshot_bytes(&resumed),
                "N={n} cut={cut}: continued original vs resumed"
            );
        });
    }
}

/// End-to-end crash drill on the real binary: a `--checkpoint` monitor
/// killed by an injected `monitor-exit` fault (exit 86, right after a
/// snapshot+checkpoint) must `--resume` and finish with a final snapshot
/// byte-identical to a monitor that never died.
#[test]
fn killed_monitor_resumes_to_the_uninterrupted_snapshot() {
    let dir = scratch("crash");
    let stream = dir.join("stream.txt");
    let ok = Command::new(bin())
        .args(["monitor", "record", "--days", "0.1", "--seed", "91", "--arrivals-per-hour", "6"])
        .args(["--out", &stream.display().to_string()])
        .status()
        .expect("spawning tpufleet")
        .success();
    assert!(ok, "monitor record failed");
    let snap = dir.join("snap.json");
    let ckpt = dir.join("mon.ckpt");
    let monitor_args = |cmd: &mut Command| {
        cmd.args(["monitor", "--in", &stream.display().to_string()]);
        cmd.args(["--width-s", "900", "--ring-windows", "4", "--snapshot-every", "600"]);
        cmd.args(["--out", &snap.display().to_string()]);
        cmd.args(["--checkpoint", &ckpt.display().to_string()]);
    };
    let mut doomed = Command::new(bin());
    monitor_args(&mut doomed);
    doomed.args(["--inject-faults", "monitor-exit:after=2"]);
    let output = doomed.output().expect("spawning tpufleet");
    assert_eq!(
        output.status.code(),
        Some(INJECTED_EXIT_CODE),
        "injected monitor-exit must kill the process: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    assert!(ckpt.exists(), "the doomed run must leave a checkpoint behind");
    let mut resumed = Command::new(bin());
    monitor_args(&mut resumed);
    resumed.args(["--resume", &ckpt.display().to_string()]);
    let output = resumed.output().expect("spawning tpufleet");
    assert!(
        output.status.success(),
        "resume failed: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    assert!(
        String::from_utf8_lossy(&output.stderr).contains("resumed from"),
        "resume must announce itself on stderr"
    );
    let resumed_snap = read(&snap);
    let clean = dir.join("clean.json");
    let ok = Command::new(bin())
        .args(["monitor", "--in", &stream.display().to_string()])
        .args(["--width-s", "900", "--ring-windows", "4"])
        .args(["--out", &clean.display().to_string()])
        .status()
        .expect("spawning tpufleet")
        .success();
    assert!(ok, "clean one-shot monitor failed");
    assert_eq!(resumed_snap, read(&clean), "resumed final snapshot vs never-died run");
    // Version skew is refused, not half-read: rewrite the checkpoint
    // with a bumped layout version and watch --resume walk away.
    let skewed = read(&ckpt).replacen("\"ckpt_version\": 1", "\"ckpt_version\": 99", 1);
    std::fs::write(&ckpt, skewed).unwrap();
    let mut stale = Command::new(bin());
    monitor_args(&mut stale);
    stale.args(["--resume", &ckpt.display().to_string()]);
    let output = stale.output().expect("spawning tpufleet");
    assert_eq!(output.status.code(), Some(1));
    let err = String::from_utf8_lossy(&output.stderr);
    assert!(err.contains("refusing to resume"), "{err}");
}

/// A garbled stream aborts a strict merge with the offending stream and
/// line named — and under `--quarantine` the same merge finishes,
/// isolating the bad stream while the healthy one still lands in the
/// snapshot.
#[test]
fn quarantine_survives_a_garbled_stream_that_kills_strict_mode() {
    let dir = scratch("quarantine");
    let mut inputs = Vec::new();
    for (i, seed) in [0x61u64, 0x62].iter().enumerate() {
        let out = dir.join(format!("cell{i}.txt"));
        let ok = Command::new(bin())
            .args(["monitor", "record", "--days", "0.1", "--arrivals-per-hour", "6"])
            .args(["--seed", &seed.to_string()])
            .args(["--stream-id", &format!("cell-{i}")])
            .args(["--out", &out.display().to_string()])
            .status()
            .expect("spawning tpufleet")
            .success();
        assert!(ok, "monitor record failed");
        inputs.push(out);
    }
    // Garble one span line mid-way through stream 1.
    let text = read(&inputs[1]);
    let victim = text
        .lines()
        .filter(|l| l.starts_with("span "))
        .nth(20)
        .expect("stream 1 has at least 21 spans");
    let garbled = text.replacen(victim, "span but not as we know it", 1);
    std::fs::write(&inputs[1], garbled).unwrap();
    let in_arg = format!("{},{}", inputs[0].display(), inputs[1].display());
    let snap = dir.join("merged.json");
    let merge_cmd = |extra: &[&str]| {
        let mut cmd = Command::new(bin());
        cmd.args(["monitor", "--merge", "--in", &in_arg]);
        cmd.args(["--width-s", "900", "--ring-windows", "4"]);
        cmd.args(["--out", &snap.display().to_string()]);
        cmd.args(extra);
        cmd.output().expect("spawning tpufleet")
    };
    let strict = merge_cmd(&[]);
    assert_eq!(strict.status.code(), Some(1), "strict mode must abort on garbage");
    let err = String::from_utf8_lossy(&strict.stderr);
    assert!(err.contains("cell-1"), "strict error names the stream: {err}");
    let lenient = merge_cmd(&["--quarantine"]);
    let err = String::from_utf8_lossy(&lenient.stderr);
    assert!(lenient.status.success(), "--quarantine must survive: {err}");
    assert!(err.contains("quarantining stream `cell-1`"), "{err}");
    let doc = Json::parse(&read(&snap)).expect("merged snapshot parses");
    assert_eq!(doc.get("final").as_bool(), Some(true));
    assert!(
        doc.get("fleet").get("mpg").as_f64().is_some(),
        "the healthy stream still produces a fleet report"
    );
}
