//! Property tests on coordinator invariants (in-tree testkit; the offline
//! build has no proptest — see DESIGN.md §9).

use tpufleet::fleet::{pod::axis_permutations, ChipGeneration, Fleet, Pod, SliceId};
use tpufleet::metrics::goodput;
use tpufleet::metrics::{JobMeta, Ledger, TimeClass};
use tpufleet::runtime_model::{EraEffects, RuntimeModel, WindowEnd};
use tpufleet::scheduler::{Scheduler, SchedulerPolicy};
use tpufleet::testkit::check;
use tpufleet::util::{Json, Rng};
use tpufleet::workload::{
    CheckpointPolicy, Framework, Job, ModelArch, Phase, Priority, StepProfile,
};

fn random_job(rng: &mut Rng, id: u64, gen: ChipGeneration) -> Job {
    let pod = gen.spec().pod_shape;
    let (slice_shape, pods) = if rng.chance(0.25) {
        ([0, 0, 0], rng.range_u64(1, 3) as u32)
    } else {
        let s = [
            rng.range_u64(1, pod[0] as u64) as u32,
            rng.range_u64(1, pod[1] as u64) as u32,
            rng.range_u64(1, pod[2] as u64) as u32,
        ];
        (s, 0)
    };
    let phases = [Phase::Training, Phase::Serving, Phase::BulkInference];
    let prios = [Priority::Batch, Priority::Prod, Priority::Critical];
    Job {
        id,
        arrival_s: rng.range_f64(0.0, 1000.0),
        phase: phases[rng.below(3) as usize],
        framework: Framework::ALL[rng.below(3) as usize],
        arch: ModelArch::ALL[rng.below(4) as usize],
        priority: prios[rng.below(3) as usize],
        gen,
        slice_shape,
        pods,
        work_s: rng.range_f64(100.0, 20_000.0),
        step: StepProfile {
            ideal_flops_per_chip: rng.range_f64(1e10, 1e13),
            base_efficiency: rng.range_f64(0.1, 0.9),
            comm_fraction: rng.range_f64(0.0, 0.7),
            host_fraction: rng.range_f64(0.0, 0.6),
        },
        ckpt: if rng.chance(0.5) {
            CheckpointPolicy::synchronous()
        } else {
            CheckpointPolicy::asynchronous()
        },
        startup_s: rng.range_f64(10.0, 600.0),
    }
}

/// Scheduler never double-books a chip and conserves capacity across an
/// arbitrary sequence of submit / schedule / complete / evict / defrag ops.
#[test]
fn prop_scheduler_never_double_books() {
    check(60, 0xA11C, |rng| {
        let gen = ChipGeneration::TpuC;
        let mut fleet = Fleet::new();
        fleet.add_pods(gen, rng.range_u64(2, 6) as u32);
        let total = fleet.total_chips();
        let mut sched = Scheduler::new(SchedulerPolicy {
            min_runtime_before_evict_s: 0.0,
            ..Default::default()
        });
        let mut next_id = 1u64;
        let mut live: Vec<u64> = Vec::new();
        for step in 0..rng.range_u64(10, 60) {
            let now = step as f64 * 100.0;
            match rng.below(10) {
                0..=4 => {
                    let job = random_job(rng, next_id, gen);
                    live.push(next_id);
                    next_id += 1;
                    sched.submit(job);
                }
                5..=6 => {
                    sched.schedule(&mut fleet, now);
                }
                7 => {
                    if !live.is_empty() {
                        let idx = rng.below(live.len() as u64) as usize;
                        let id = live.swap_remove(idx);
                        sched.complete(&mut fleet, id);
                    }
                }
                8 => {
                    if !live.is_empty() {
                        let idx = rng.below(live.len() as u64) as usize;
                        sched.evict(&mut fleet, live[idx]);
                    }
                }
                _ => {
                    sched.defrag(&mut fleet, now, 2);
                }
            }
            sched.check_invariants(&fleet).unwrap();
            // Capacity conservation: allocated + free == total.
            let allocated: u64 =
                sched.running_jobs().map(|(_, a)| a.chips() as u64).sum();
            let free = fleet.cell(gen).unwrap().free_chips();
            assert_eq!(allocated + free, total, "capacity leak at step {step}");
        }
    });
}

/// Slice carving: any claimed slice's chips are within pod bounds, and two
/// simultaneously claimed slices never overlap.
#[test]
fn prop_torus_slices_never_overlap() {
    check(100, 0x70F0, |rng| {
        let mut pod = Pod::new(0, ChipGeneration::TpuC);
        let mut claimed: Vec<SliceId> = Vec::new();
        for id in 1..rng.range_u64(2, 20) {
            let shape = [
                rng.range_u64(1, 4) as u32,
                rng.range_u64(1, 4) as u32,
                rng.range_u64(1, 4) as u32,
            ];
            if let Some(slice) = pod.find_slice(shape) {
                pod.claim(slice, id);
                claimed.push(slice);
            }
        }
        // Overlap check via explicit coordinate sets.
        let cells = |s: &SliceId| -> Vec<[u32; 3]> {
            let mut v = Vec::new();
            for z in s.origin[2]..s.origin[2] + s.shape[2] {
                for y in s.origin[1]..s.origin[1] + s.shape[1] {
                    for x in s.origin[0]..s.origin[0] + s.shape[0] {
                        v.push([x, y, z]);
                    }
                }
            }
            v
        };
        let mut seen = std::collections::HashSet::new();
        for s in &claimed {
            for c in cells(s) {
                assert!(c[0] < 4 && c[1] < 4 && c[2] < 4, "out of bounds {c:?}");
                assert!(seen.insert(c), "overlap at {c:?}");
            }
        }
    });
}

/// axis_permutations always yields shapes with identical volume, all unique.
#[test]
fn prop_axis_permutations_preserve_volume() {
    check(200, 0xAAA, |rng| {
        let s = [
            rng.range_u64(1, 16) as u32,
            rng.range_u64(1, 16) as u32,
            rng.range_u64(1, 16) as u32,
        ];
        let vol: u32 = s.iter().product();
        let perms = axis_permutations(s);
        assert!(!perms.is_empty() && perms.len() <= 6);
        for p in &perms {
            assert_eq!(p.iter().product::<u32>(), vol);
        }
        let unique: std::collections::HashSet<_> = perms.iter().collect();
        assert_eq!(unique.len(), perms.len());
    });
}

/// Runtime-model accounting conserves time: pieces sum to the window (or
/// less, only when completed early), and saved work never decreases or
/// exceeds the job's total.
#[test]
fn prop_runtime_accounting_conserves_time() {
    check(300, 0xACC7, |rng| {
        let rm = RuntimeModel::default();
        let job = random_job(rng, 1, ChipGeneration::TpuC);
        let work_done = rng.range_f64(0.0, job.work_s);
        let window = rng.range_f64(0.0, 3.0 * job.work_s + 2.0 * job.startup_s);
        let end = if rng.chance(0.5) { WindowEnd::Evicted } else { WindowEnd::Completed };
        let era = EraEffects {
            stall_mult: rng.range_f64(0.2, 5.0),
            restore_mult: rng.range_f64(0.2, 5.0),
            compile_mult: rng.range_f64(0.2, 5.0),
            ckpt_mult: rng.range_f64(0.2, 5.0),
        };
        let acct = rm.account(&job, rng.chance(0.5), work_done, window, end, &era);
        let total: f64 = acct.pieces.iter().map(|(_, _, d)| d).sum();
        assert!(total <= window + 1e-6, "pieces exceed window: {total} > {window}");
        if !acct.completed {
            assert!(
                (total - window).abs() < 1e-6,
                "uncompleted window must be fully classified: {total} vs {window}"
            );
        }
        assert!(acct.work_done_after >= work_done - 1e-9, "work regressed");
        assert!(acct.work_done_after <= job.work_s + 1e-9, "work overshoot");
        for (_, _, d) in &acct.pieces {
            assert!(*d >= -1e-12, "negative piece {d}");
        }
    });
}

/// Goodput reduction: SG/RG/PG always in [0,1] and MPG multiplies, under
/// arbitrary ledgers and windows.
#[test]
fn prop_goodput_bounded_under_arbitrary_ledgers() {
    check(150, 0x60D0, |rng| {
        let mut ledger = Ledger::new();
        ledger.set_capacity(0.0, rng.range_u64(100, 10_000));
        let n_jobs = rng.range_u64(1, 12);
        for id in 1..=n_jobs {
            let job = random_job(rng, id, ChipGeneration::TpuC);
            ledger.ensure_job(JobMeta::of(&job));
            let mut t = rng.range_f64(0.0, 100.0);
            for _ in 0..rng.range_u64(0, 10) {
                let dur = rng.range_f64(0.1, 500.0);
                let class = TimeClass::ALL[rng.below(7) as usize];
                let chips = job.chips();
                ledger.add_span_auto(id, t, t + dur, chips, class);
                if class == TimeClass::Productive {
                    ledger.add_pg_sample(id, t, t + dur, chips, rng.range_f64(0.0, 1.0));
                }
                t += dur;
            }
        }
        let end = ledger.end_time().max(1.0);
        for _ in 0..5 {
            let w0 = rng.range_f64(0.0, end);
            let w1 = rng.range_f64(0.0, end);
            let r = goodput::report(&ledger, w0.min(w1), w0.max(w1), |_| true);
            for v in [r.sg, r.rg, r.pg] {
                assert!((0.0..=1.0).contains(&v), "out of range: {v}");
            }
            assert!((r.mpg() - r.sg * r.rg * r.pg).abs() < 1e-12);
        }
    });
}

/// JSON round-trip fuzz: random values survive serialize -> parse.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let len = rng.below(12);
                let s: String =
                    (0..len).map(|_| (rng.below(95) as u8 + 32) as char).collect();
                Json::Str(s)
            }
            4 => {
                let n = rng.below(4);
                Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.below(4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    check(300, 0x150_u64, |rng| {
        let v = random_json(rng, 3);
        let compact = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, compact);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    });
}

/// Simulator determinism: identical configs (any seed) produce identical
/// results and goodput decompositions.
#[test]
fn prop_sim_deterministic_any_seed() {
    use tpufleet::sim::{SimConfig, Simulation};
    check(6, 0xDE7, |rng| {
        let mut cfg = SimConfig {
            seed: rng.next_u64(),
            duration_s: 36.0 * 3600.0,
            ..Default::default()
        };
        cfg.generator.arrivals_per_hour = rng.range_f64(4.0, 16.0);
        cfg.static_fleet = vec![(ChipGeneration::TpuC, rng.range_u64(8, 24) as u32)];
        cfg.generator.gen_mix = vec![(ChipGeneration::TpuC, 1.0)];
        let mut a = Simulation::new(cfg.clone());
        let ra = a.run();
        let mut b = Simulation::new(cfg.clone());
        let rb = b.run();
        assert_eq!(ra.completed_jobs, rb.completed_jobs);
        assert_eq!(ra.preemptions, rb.preemptions);
        let ga = goodput::report(&a.ledger, 0.0, cfg.duration_s, |_| true);
        let gb = goodput::report(&b.ledger, 0.0, cfg.duration_s, |_| true);
        assert_eq!(ga, gb);
    });
}
