//! Integration: MPG pipeline from simulator ledger through segmented
//! reports — the paper's measurement methodology end to end.

use tpufleet::fleet::ChipGeneration;
use tpufleet::metrics::goodput::{self, Axis};
use tpufleet::metrics::{TimeClass, TimeSeries};
use tpufleet::runtime_model::EraEffects;
use tpufleet::sim::{EraRule, SimConfig, Simulation};
use tpufleet::workload::Phase;
use tpufleet::xlaopt::{CompilerStack, Pass};

fn base_cfg(days: f64, seed: u64) -> SimConfig {
    let mut cfg = SimConfig {
        seed,
        duration_s: days * 24.0 * 3600.0,
        ..Default::default()
    };
    cfg.generator.arrivals_per_hour = 8.0;
    cfg
}

#[test]
fn fleet_report_is_consistent_with_ledger_totals() {
    let cfg = base_cfg(3.0, 11);
    let mut sim = Simulation::new(cfg.clone());
    sim.run();
    let r = goodput::report(&sim.ledger, 0.0, cfg.duration_s, |_| true);
    // The explicit class sums must reconstruct the report's totals.
    let classes = [
        TimeClass::Productive,
        TimeClass::Startup,
        TimeClass::CkptStall,
        TimeClass::RuntimeStall,
        TimeClass::Lost,
    ];
    let alloc: f64 = classes
        .iter()
        .map(|&c| sim.ledger.class_chip_seconds(c, 0.0, cfg.duration_s, |_| true))
        .sum();
    assert!((alloc - r.all_allocated_cs).abs() < 1e-9 * r.all_allocated_cs.max(1.0));
    assert!(r.capacity_cs > 0.0);
    assert!(r.all_allocated_cs <= r.capacity_cs * 1.0 + 1e-6);
}

#[test]
fn segment_reports_partition_the_fleet() {
    let cfg = base_cfg(3.0, 12);
    let mut sim = Simulation::new(cfg.clone());
    sim.run();
    // Per-phase all-allocated chip-seconds sum to the fleet total.
    let fleet = goodput::report(&sim.ledger, 0.0, cfg.duration_s, |_| true);
    let sum_phases: f64 = Phase::ALL
        .iter()
        .map(|&p| {
            goodput::report(&sim.ledger, 0.0, cfg.duration_s, |m| m.phase == p)
                .all_allocated_cs
        })
        .sum();
    assert!(
        (sum_phases - fleet.all_allocated_cs).abs() < 1e-9 * fleet.all_allocated_cs.max(1.0),
        "{sum_phases} vs {}",
        fleet.all_allocated_cs
    );
    // Segmented view must include the fleet row plus >= 2 phases.
    let segs = goodput::segmented(&sim.ledger, 0.0, cfg.duration_s, Axis::Phase);
    assert!(segs.len() >= 3);
    assert_eq!(segs[0].label, "fleet");
}

#[test]
fn async_checkpointing_improves_rg() {
    // The §5.2 claim, on the full simulator: flip the fleet's checkpoint
    // strategy and watch RG move.
    let mut sync_cfg = base_cfg(4.0, 13);
    sync_cfg.generator.async_ckpt_fraction = 0.0;
    sync_cfg.failures = false;
    let mut async_cfg = sync_cfg.clone();
    async_cfg.generator.async_ckpt_fraction = 1.0;

    let mut s1 = Simulation::new(sync_cfg.clone());
    s1.run();
    let mut s2 = Simulation::new(async_cfg.clone());
    s2.run();
    let rg_sync = goodput::report(&s1.ledger, 0.0, sync_cfg.duration_s, |_| true).rg;
    let rg_async = goodput::report(&s2.ledger, 0.0, async_cfg.duration_s, |_| true).rg;
    assert!(
        rg_async > rg_sync,
        "async checkpointing should raise RG: {rg_sync} -> {rg_async}"
    );
}

#[test]
fn compiler_pass_improves_pg_in_sim() {
    let mut cfg = base_cfg(4.0, 14);
    cfg.failures = false;
    let mut opt_cfg = cfg.clone();
    let mut stack = CompilerStack::new();
    stack.deploy(Pass::AlgebraicSimplification, 0.0);
    stack.deploy(Pass::CollectiveOverlap, 0.0);
    stack.deploy(Pass::Autotune, 0.0);
    opt_cfg.compiler = stack;

    let mut s1 = Simulation::new(cfg.clone());
    s1.run();
    let mut s2 = Simulation::new(opt_cfg.clone());
    s2.run();
    let pg0 = goodput::report(&s1.ledger, 0.0, cfg.duration_s, |_| true).pg;
    let pg1 = goodput::report(&s2.ledger, 0.0, cfg.duration_s, |_| true).pg;
    assert!(pg1 > pg0 * 1.03, "compiler stack should raise PG: {pg0} -> {pg1}");
}

#[test]
fn era_regression_shows_up_in_windowed_series() {
    let mut cfg = base_cfg(6.0, 15);
    cfg.failures = false;
    // Bad era in the second half for bulk inference.
    let half = cfg.duration_s / 2.0;
    cfg.eras.add(EraRule {
        t0: half,
        t1: cfg.duration_s,
        phase: Some(Phase::BulkInference),
        effects: EraEffects { stall_mult: 8.0, restore_mult: 5.0, ..Default::default() },
    });
    let mut sim = Simulation::new(cfg.clone());
    sim.run();
    let ts = TimeSeries::build(
        "bulk",
        &sim.ledger,
        0.0,
        cfg.duration_s,
        cfg.duration_s / 2.0,
        |m| m.phase == Phase::BulkInference,
    );
    let rg = ts.rg_values();
    assert_eq!(rg.len(), 2);
    assert!(
        rg[1] < rg[0] * 0.97,
        "era regression must reduce bulk-inference RG: {rg:?}"
    );
    // Training RG should be unaffected (within noise).
    let tr = TimeSeries::build(
        "train",
        &sim.ledger,
        0.0,
        cfg.duration_s,
        cfg.duration_s / 2.0,
        |m| m.phase == Phase::Training,
    )
    .rg_values();
    assert!(tr[1] > tr[0] * 0.9, "training should not crater: {tr:?}");
}

#[test]
fn headroom_policy_trades_batch_sg_for_critical_sg() {
    let mut cfg = base_cfg(3.0, 16);
    cfg.failures = false;
    cfg.generator.arrivals_per_hour = 14.0; // contention
    let mut headroom_cfg = cfg.clone();
    headroom_cfg.policy.headroom_fraction = 0.15;

    let run = |cfg: &SimConfig| {
        let mut sim = Simulation::new(cfg.clone());
        sim.run();
        let queued = |p: tpufleet::workload::Priority| -> f64 {
            // Use phase as a proxy: Serving == Critical in the generator.
            let _ = p;
            sim.ledger.class_chip_seconds(TimeClass::Queued, 0.0, cfg.duration_s, |m| {
                m.phase == Phase::Serving
            })
        };
        let crit_queued = queued(tpufleet::workload::Priority::Critical);
        let alloc = goodput::report(&sim.ledger, 0.0, cfg.duration_s, |m| {
            m.phase == Phase::Serving
        })
        .all_allocated_cs;
        crit_queued / (crit_queued + alloc).max(1.0)
    };
    let wait_frac_no_headroom = run(&cfg);
    let wait_frac_headroom = run(&headroom_cfg);
    // Headroom must not make critical jobs wait more (usually strictly less).
    assert!(
        wait_frac_headroom <= wait_frac_no_headroom + 0.02,
        "{wait_frac_no_headroom} -> {wait_frac_headroom}"
    );
}

#[test]
fn mpg_summary_table_renders() {
    let cfg = base_cfg(2.0, 17);
    let mut sim = Simulation::new(cfg.clone());
    sim.run();
    let table = tpufleet::report::figures::mpg_summary(&sim.ledger, 0.0, cfg.duration_s);
    let ascii = table.to_ascii();
    assert!(ascii.contains("fleet"));
    assert!(ascii.contains("training"));
    let csv = table.to_csv();
    assert!(csv.lines().count() > 3);
}

#[test]
fn rejected_oversize_jobs_are_counted() {
    let mut cfg = base_cfg(1.0, 18);
    // Tiny fleet: XL multipod jobs cannot ever fit.
    cfg.static_fleet = vec![(ChipGeneration::TpuC, 2)];
    cfg.generator.gen_mix = vec![(ChipGeneration::TpuC, 1.0)];
    cfg.generator.arrivals_per_hour = 20.0;
    let mut sim = Simulation::new(cfg);
    let res = sim.run();
    assert!(res.rejected_jobs > 0, "{res:?}");
}
